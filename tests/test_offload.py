"""Surrogate-offload routing + the GP correctness fixes behind it.

Regression coverage for the three bugfixes (pooled multi-output variance
scale, `flatten_parameters` returning [] for empty payloads, pooled
straggler p95 across heterogeneous models) plus determinism and
trust-gating of the offload path in both the discrete-event simulator
and the live executor, and the bucketed-shape discipline of
`gp.predict_batch`.
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import Broker, TraceTask, simulate_cluster
from repro.core import backends, metrics
from repro.core.executor import Executor
from repro.core.task import EvalRequest, EvalResult, LambdaModel
from repro.sched.offload import SurrogateOffload, SurrogateOffloadPolicy
from repro.sched.predictor import GPRuntimePredictor, flatten_parameters
from repro.uq import gp as gp_lib


# --------------------------------------------------------------------------
# bugfix 1: per-output posterior variance
# --------------------------------------------------------------------------
def _analytic_1pt_posterior():
    """A hand-built single-training-point GP with output scales 1 and 10,
    so every quantity has a closed form."""
    params = gp_lib.GPParams.init(1)            # ls=1, sf=1, noise=0.1
    sf, s2 = 1.0, 0.01
    jitter = s2 + 1e-5 * (sf + 1.0)
    x = jnp.array([[0.0]], jnp.float32)
    y = jnp.array([[1.0, 10.0]], jnp.float32)
    y_mean = jnp.array([0.0, 0.0], jnp.float32)
    y_std = jnp.array([1.0, 10.0], jnp.float32)
    k11 = sf + jitter
    chol = jnp.array([[np.sqrt(k11)]], jnp.float32)
    yn = (y - y_mean) / y_std                   # [[1, 1]]
    alpha = yn / k11
    post = gp_lib.GPPosterior(params=params, x=x, y=y, y_mean=y_mean,
                              y_std=y_std, chol=chol, alpha=alpha)
    return post, sf, k11


@pytest.mark.parametrize("predict_fn", [gp_lib.predict, gp_lib.predict_batch])
def test_multioutput_variance_matches_analytic_1pt(predict_fn):
    """Variance must be [S, M], each column scaled by ITS OWN y_std^2 —
    the pooled mean(y_std)^2 scale was wrong for every column."""
    post, sf, k11 = _analytic_1pt_posterior()
    xs = np.array([[0.0], [0.7]], np.float32)
    mean, var = predict_fn(post, xs)
    assert mean.shape == (2, 2) and var.shape == (2, 2)
    kstar = np.exp(-0.5 * xs[:, 0] ** 2)
    latent = np.maximum(sf - kstar ** 2 / k11, 1e-12)
    expected = latent[:, None] * np.array([1.0, 100.0])[None, :]
    np.testing.assert_allclose(np.asarray(var), expected,
                               rtol=1e-4, atol=1e-6)
    expected_mean = (kstar / k11)[:, None] * np.array([1.0, 10.0])[None, :]
    np.testing.assert_allclose(np.asarray(mean), expected_mean,
                               rtol=1e-4, atol=1e-6)


def test_multioutput_variance_scales_per_output_after_fit():
    """With y2 = 100*y1 the stds differ by exactly 100x, so correct
    per-output variances differ by exactly 1e4 — pooling cannot."""
    rng = np.random.default_rng(0)
    x = rng.random((20, 2)).astype(np.float32)
    y1 = np.sin(3 * x[:, 0]) + x[:, 1]
    y = np.stack([y1, 100.0 * y1], 1)
    post = gp_lib.fit(x, y, steps=60)
    _, var = gp_lib.predict(post, rng.random((5, 2)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(var)[:, 1],
                               1e4 * np.asarray(var)[:, 0], rtol=1e-4)


# --------------------------------------------------------------------------
# bugfix 2: empty payloads must not poison the GP predictor's feature dim
# --------------------------------------------------------------------------
def test_flatten_parameters_empty_is_none():
    assert flatten_parameters([]) is None
    assert flatten_parameters([[]]) is None
    assert flatten_parameters(((),)) is None
    assert flatten_parameters([[1.0, 2.0]]) == [1.0, 2.0]
    assert flatten_parameters("nope") is None


def test_gp_predictor_not_poisoned_by_empty_payload():
    pred = GPRuntimePredictor(min_fit=4, fit_steps=20)
    empty = EvalRequest("m", [[]])
    for _ in range(3):
        pred.observe(empty, 1.0)               # degenerate: must be skipped
    assert pred._dim is None                   # dim NOT locked to 0
    rng = np.random.default_rng(1)
    for _ in range(6):
        r = EvalRequest("m", [rng.random(2).tolist()])
        pred.observe(r, 2.0)
    assert pred._dim == 2                      # real features won the dim
    assert pred._post is not None              # ...and the GP actually fit
    est = pred.predict(EvalRequest("m", [rng.random(2).tolist()]))
    assert est == pytest.approx(2.0, rel=0.5)


# --------------------------------------------------------------------------
# bugfix 3: straggler threshold is per model, pooled only as fallback
# --------------------------------------------------------------------------
def test_straggler_threshold_is_per_model():
    """60 fast completions + 3 slow ones: the pooled p95 is the FAST
    runtime, so the old pooled cutoff would speculatively re-issue every
    healthy slow-model task; the per-model cutoff must not."""
    with Executor({}, n_workers=0, straggler_factor=3.0,
                  straggler_min_completed=3) as ex:
        with ex._lock:
            for i in range(60):
                tid = f"fast-{i}"
                ex._requests[tid] = EvalRequest("fast", [[0.0]], task_id=tid)
                ex._results[tid] = EvalResult(task_id=tid, status="ok",
                                              compute_t=0.01)
            for i in range(3):
                tid = f"slow-{i}"
                ex._requests[tid] = EvalRequest("slow", [[0.0]], task_id=tid)
                ex._results[tid] = EvalResult(task_id=tid, status="ok",
                                              compute_t=1.0)
            now = time.monotonic()
            slow_run = EvalRequest("slow", [[0.0]], task_id="slow-run")
            fast_run = EvalRequest("fast", [[0.0]], task_id="fast-run")
            # both have been running 0.5 s: far beyond 3x the fast p95
            # (0.03 s), well within 3x the slow p95 (3 s)
            ex._running["slow-run"] = (slow_run, None, now - 0.5, 1)
            ex._running["fast-run"] = (fast_run, None, now - 0.5, 1)
        ex._straggler_check(now)
        assert fast_run.config.get("_speculated")      # true straggler
        assert not slow_run.config.get("_speculated")  # healthy slow model


def test_straggler_pooled_fallback_for_unknown_model():
    """A model with too few completions of its own still gets straggler
    protection from the pooled p95."""
    with Executor({}, n_workers=0, straggler_factor=3.0,
                  straggler_min_completed=3) as ex:
        with ex._lock:
            for i in range(10):
                tid = f"fast-{i}"
                ex._requests[tid] = EvalRequest("fast", [[0.0]], task_id=tid)
                ex._results[tid] = EvalResult(task_id=tid, status="ok",
                                              compute_t=0.01)
            now = time.monotonic()
            new_run = EvalRequest("new-model", [[0.0]], task_id="new-run")
            ex._running["new-run"] = (new_run, None, now - 0.5, 1)
        ex._straggler_check(now)
        assert new_run.config.get("_speculated")


# --------------------------------------------------------------------------
# predict_batch: bucketed padding caps the compile-shape count
# --------------------------------------------------------------------------
def test_predict_batch_bucket_shape_discipline():
    rng = np.random.default_rng(2)
    x = rng.random((24, 3)).astype(np.float32)
    y = np.stack([np.sin(2 * x[:, 0]), x[:, 1] - x[:, 2]], 1)
    post = gp_lib.fit(x, y, steps=40)

    gp_lib.predict_batch_shapes.clear()
    total = 0
    for size in (1, 2, 9, 40, 64, 65, 131, 300, 512):  # a queue's lifetime
        xs = rng.random((size, 3)).astype(np.float32)
        mean_b, var_b = gp_lib.predict_batch(post, xs)
        assert mean_b.shape == (size, 2) and var_b.shape == (size, 2)
        total += size
    assert total >= 512                        # scored a 512+-task queue
    # bucketed padding: at most 3 distinct launch shapes, never one per size
    assert len(gp_lib.predict_batch_shapes) <= 3

    xs = rng.random((37, 3)).astype(np.float32)
    mean_b, var_b = gp_lib.predict_batch(post, xs)
    mean_p, var_p = gp_lib.predict(post, xs)
    np.testing.assert_allclose(np.asarray(mean_b), np.asarray(mean_p),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(var_b), np.asarray(var_p),
                               rtol=5e-2, atol=1e-6)


# --------------------------------------------------------------------------
# offload policy: trust gating
# --------------------------------------------------------------------------
def _toy_surrogate(seed=0, n=40, **kw):
    rng = np.random.default_rng(seed)
    xs = rng.random((n, 2)).astype(np.float32)
    ys = np.stack([np.sin(3 * xs[:, 0]) + xs[:, 1],
                   100.0 * np.cos(2 * xs[:, 1])], 1)
    post = gp_lib.fit(xs, ys, steps=80)
    kw.setdefault("runtime_budget_s", 30.0)
    kw.setdefault("sd_threshold", 0.2)
    return SurrogateOffload(post, **kw)


def test_offload_gates():
    sur = _toy_surrogate()
    trusted_long = EvalRequest("m", [[0.5, 0.5]], time_request=100.0)
    trusted_short = EvalRequest("m", [[0.5, 0.5]], time_request=1.0)
    untrusted_long = EvalRequest("m", [[5.0, 5.0]], time_request=100.0)
    unflat_long = EvalRequest("m", [["x"]], time_request=100.0)
    assert sur.decide(trusted_long, cost=100.0)
    assert trusted_long.config.get("_surrogate") is True
    assert not sur.decide(trusted_short, cost=1.0)      # cost gate
    assert not sur.decide(untrusted_long, cost=100.0)   # variance gate
    assert not sur.decide(unflat_long, cost=100.0)      # not in theta space
    st = sur.stats()
    assert st.n_considered == 4 and st.n_offloaded == 1
    assert st.cpu_seconds_avoided > 0
    assert sum(st.sd_histogram["counts"]) == 2          # two trust checks
    # a re-decision that says "no" clears a stale flag
    assert not sur.decide(trusted_long, cost=1.0)
    assert "_surrogate" not in trusted_long.config


def test_offload_scoped_to_model():
    """A scoped engine must neither serve another model from the wrong
    surrogate nor condition on its completions."""
    sur = _toy_surrogate(model_name="gs2")
    other = EvalRequest("other", [[0.5, 0.5]], time_request=100.0)
    mine = EvalRequest("gs2", [[0.5, 0.5]], time_request=100.0)
    assert not sur.decide(other, cost=100.0)
    assert sur.decide(mine, cost=100.0)
    n_before = int(sur.posterior.x.shape[0])
    sur.condition_every = 1
    sur.observe([[0.5, 0.5]], [[1.0, 1.0]], model_name="other")
    assert int(sur.posterior.x.shape[0]) == n_before   # ignored
    sur.observe([[0.5, 0.5]], [[1.0, 1.0]], model_name="gs2")
    assert int(sur.posterior.x.shape[0]) == n_before + 1


def test_offload_no_surrogate_pin():
    """`_no_surrogate` (set after a surrogate failure / by straggler
    speculation) pins a task to the real path across re-decisions."""
    sur = _toy_surrogate()
    req = EvalRequest("m", [[0.5, 0.5]], time_request=100.0)
    assert sur.decide(req, cost=100.0)
    req.config["_no_surrogate"] = True
    assert not sur.decide(req, cost=100.0)
    assert "_surrogate" not in req.config


def test_offload_credit_idempotent_across_requeues():
    """A requeued attempt re-decides but must not double-count the task
    or its avoided-CPU credit; a later 'no' refunds the credit."""
    sur = _toy_surrogate()
    req = EvalRequest("m", [[0.5, 0.5]], time_request=100.0)
    assert sur.decide(req, cost=100.0)
    assert sur.decide(req, cost=100.0)         # requeue after a crash
    st = sur.stats()
    assert st.n_offloaded == 1
    assert st.cpu_seconds_avoided == pytest.approx(100.0 - sur.latency_s)
    # the retry lands on the real path after all: credit refunded
    req.config["_no_surrogate"] = True
    assert not sur.decide(req, cost=100.0)
    st = sur.stats()
    assert st.n_offloaded == 0
    assert st.cpu_seconds_avoided == pytest.approx(0.0)


def test_offload_observe_caps_training_set():
    """Conditioning keeps the most recent `max_points` observations —
    the posterior must not grow (and recompile) without bound."""
    sur = _toy_surrogate(condition_every=1, max_points=42)
    for i in range(6):
        x = 0.01 * i
        sur.observe([[x, x]], [[1.0, 1.0]], model_name=None)
    assert int(sur.posterior.x.shape[0]) == 42
    # the newest observation survived the trim
    assert float(sur.posterior.x[-1, 0]) == pytest.approx(0.05)


def test_offload_unarmed_engine_is_passthrough():
    sur = SurrogateOffload()                   # no posterior
    req = EvalRequest("m", [[0.5, 0.5]], time_request=1000.0)
    assert not sur.decide(req, cost=1000.0)
    pol = SurrogateOffloadPolicy(policy="fcfs", surrogate=sur)
    pol.push(req, 1)
    assert len(pol) == 1 and pol.pop() == (req, 1)


def test_offload_policy_fast_lane():
    pol = SurrogateOffloadPolicy(policy="fcfs", surrogate=_toy_surrogate())
    normal = EvalRequest("m", [[5.0, 5.0]], time_request=100.0)
    offl = EvalRequest("m", [[0.5, 0.5]], time_request=100.0)
    pol.push(normal, 1)
    pol.push(offl, 1)
    assert len(pol) == 2
    # the offloaded task pops FIRST even though it arrived second
    assert pol.pop()[0] is offl
    assert pol.pop()[0] is normal


# --------------------------------------------------------------------------
# offload in the simulator: determinism + accounting
# --------------------------------------------------------------------------
def _offload_trace(n=30, seed=7):
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(4.0))
        lng = rng.uniform() < 0.4
        theta = rng.random(2) if rng.uniform() < 0.7 else 3.0 + rng.random(2)
        out.append(TraceTask(t=t, runtime=90.0 if lng else 3.0,
                             model_name="gs2",
                             time_request=90.0 if lng else 3.0,
                             parameters=[[float(theta[0]),
                                          float(theta[1])]]))
    return out


def _run_sim_offload(trace, seed=0):
    sur = _toy_surrogate(latency_s=0.05)
    broker = Broker(policy="fcfs", surrogate=sur)
    res = simulate_cluster(backends.get("hq"), trace, broker=broker,
                           n_workers=3, seed=seed)
    return res, sur


def test_sim_offload_deterministic_and_saves_cpu():
    trace = _offload_trace()
    base = simulate_cluster(backends.get("hq"), trace, n_workers=3, seed=0)
    res1, sur1 = _run_sim_offload(trace)
    res2, sur2 = _run_sim_offload(trace)
    key = lambda r: (r.task_id, r.start_t, r.end_t, r.worker, r.status)  # noqa: E731
    assert [key(r) for r in res1.records] == [key(r) for r in res2.records]
    assert sur1.stats().n_offloaded == sur2.stats().n_offloaded > 0
    assert res1.summary()["n_ok"] == res1.summary()["n_tasks"]
    # offloaded tasks ran at surrogate latency on the virtual allocation
    offloaded = [r for r in res1.records if r.worker.startswith("alloc0-")]
    assert len(offloaded) == sur1.stats().n_offloaded
    assert all(r.cpu_time == pytest.approx(0.05) for r in offloaded)
    # ...and the run burned strictly less CPU than the baseline
    assert metrics.total_cpu_time(res1.records) < \
        0.8 * metrics.total_cpu_time(base.records)
    # the virtual allocation bills zero node-seconds
    virt = [a for a in res1.allocations if a.alloc_id == 0]
    assert virt and virt[0].node_seconds == 0.0


def test_sim_offload_with_autoalloc_ignores_virtual():
    """The autoallocator must neither drain the virtual allocation nor
    count it as capacity."""
    from repro.cluster import AutoAllocConfig
    trace = _offload_trace(n=20, seed=3)
    sur = _toy_surrogate()
    broker = Broker(policy="fcfs", surrogate=sur)
    res = simulate_cluster(
        backends.get("hq"), trace, broker=broker,
        autoalloc=AutoAllocConfig(workers_per_alloc=2, walltime_s=600.0,
                                  backlog_high_s=20.0, backlog_low_s=5.0,
                                  idle_drain_s=20.0, hysteresis_s=5.0),
        seed=0)
    assert res.summary()["n_ok"] == res.summary()["n_tasks"]
    assert sur.stats().n_offloaded > 0
    assert all(d["alloc_id"] != 0 for d in res.decisions)


# --------------------------------------------------------------------------
# offload in the live executor
# --------------------------------------------------------------------------
def _truth(x):
    return [float(np.sin(3 * x[0]) + x[1]), float(100.0 * np.cos(2 * x[1]))]


def _slow_factory():
    def fn(parameters, config):
        time.sleep(0.1)
        return [_truth(np.asarray(parameters[0], float))]
    return LambdaModel("slow", fn, 2, 2)


def test_live_offload_policy_mode():
    rng = np.random.default_rng(4)
    sur = _toy_surrogate(latency_s=0.0)
    pol = SurrogateOffloadPolicy(policy="fcfs", surrogate=sur)
    with Executor({"slow": _slow_factory}, n_workers=2, policy=pol) as ex:
        trusted = [EvalRequest("slow", [rng.random(2).tolist()],
                               time_request=100.0) for _ in range(4)]
        untrusted = [EvalRequest("slow", [[4.0, 4.0]], time_request=100.0)]
        res = ex.run_all(trusted + untrusted, timeout=60)
        assert all(r.status == "ok" for r in res)
        off = [r for r in res if r.worker.endswith("-surrogate")]
        assert len(off) == 4                   # every trusted task offloaded
        assert not res[-1].worker.endswith("-surrogate")
        # surrogate answers are near the truth (normalised by output scale)
        for r, rq in zip(res[:4], trusted):
            want = np.asarray(_truth(np.asarray(rq.parameters[0])))
            err = np.abs(np.asarray(r.value[0]) - want) / np.array([1., 100.])
            assert np.all(err < 0.25), (r.value, want)
        m = ex.metrics()
        assert m["offload"]["n_offloaded"] == 4
        assert m["offload"]["cpu_seconds_avoided"] > 0


def test_live_offload_broker_mode():
    rng = np.random.default_rng(5)
    sur = _toy_surrogate(latency_s=0.0)
    broker = Broker(policy="fcfs", surrogate=sur)
    with Executor({"slow": _slow_factory}, n_workers=2,
                  cluster=broker) as ex:
        deadline = time.monotonic() + 5.0
        while ex.n_workers() < 3 and time.monotonic() < deadline:
            time.sleep(0.02)                   # virtual worker spin-up
        reqs = [EvalRequest("slow", [rng.random(2).tolist()],
                            time_request=100.0) for _ in range(4)]
        reqs += [EvalRequest("slow", [[4.0, 4.0]], time_request=100.0)]
        res = ex.run_all(reqs, timeout=60)
        assert all(r.status == "ok" for r in res)
        off = [r for r in res if r.worker.endswith("-surrogate")]
        assert len(off) == 4
        # the virtual allocation billed nothing
        virt = [a for a in ex.allocation_records() if a.alloc_id == 0]
        assert virt and virt[0].node_seconds == 0.0


def test_live_offload_virtual_worker_respawns_after_crash():
    """The surrogate queue is served only by virtual workers; a crashed
    one must be replaced or trusted tasks would strand there forever."""
    rng = np.random.default_rng(6)
    sur = _toy_surrogate(latency_s=0.0)
    broker = Broker(policy="fcfs", surrogate=sur)
    with Executor({"slow": _slow_factory}, n_workers=1,
                  cluster=broker) as ex:
        deadline = time.monotonic() + 5.0
        while ex.n_workers() < 2 and time.monotonic() < deadline:
            time.sleep(0.02)
        virt_idx = next(i for i, w in enumerate(ex.workers)
                        if w.alloc is not None and w.alloc.virtual)
        ex.kill_worker(virt_idx)
        res = ex.run_all([EvalRequest("slow", [rng.random(2).tolist()],
                                      time_request=100.0)
                          for _ in range(3)], timeout=30)
        assert all(r.status == "ok" for r in res)
        assert sum(r.worker.endswith("-surrogate") for r in res) == 3


def test_live_offload_real_runs_condition_surrogate():
    """An untrusted theta runs the real model; its completion conditions
    the GP so the SAME theta becomes trusted."""
    sur = _toy_surrogate(latency_s=0.0, condition_every=1)
    pol = SurrogateOffloadPolicy(policy="fcfs", surrogate=sur)
    probe = [2.0, 2.0]
    with Executor({"slow": _slow_factory}, n_workers=1, policy=pol) as ex:
        sd_before = float(sur.trust_sd([probe])[0])
        assert sd_before > sur.sd_threshold
        r = ex.run_all([EvalRequest("slow", [probe],
                                    time_request=100.0)], timeout=60)[0]
        assert r.status == "ok" and not r.worker.endswith("-surrogate")
        deadline = time.monotonic() + 5.0
        while float(sur.trust_sd([probe])[0]) > sur.sd_threshold \
                and time.monotonic() < deadline:
            time.sleep(0.02)
        assert float(sur.trust_sd([probe])[0]) <= sur.sd_threshold
