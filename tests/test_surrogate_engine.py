"""Differential suite for the pluggable surrogate engines
(`repro.uq.engine`): the incremental backend is pinned to the exact
reference at tight tolerance over long seeded conditioning streams, the
partitioned backend's approximation error is bounded, the
`gp.predict_batch` bucket discipline survives every backend, the cached
triangular-inverse (`linv`) staleness contract is regression-tested, and
every consumer (offload router, runtime predictor, adaptive delegation,
Bayesian quadrature, uncertainty-aware packing) runs on each backend."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Executor, LambdaModel
from repro.core.task import EvalRequest
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.sched.offload import SurrogateOffload
from repro.sched.policy import PackingPolicy
from repro.sched.predictor import GPRuntimePredictor, QuantileEstimator
from repro.sched.registry import make_predictor
from repro.uq import adaptive
from repro.uq import engine as engine_lib
from repro.uq import gp as gp_lib
from repro.uq import qoi


def _target(x: np.ndarray) -> np.ndarray:
    return np.stack([np.sin(2.0 * x[:, 0]) + 0.5 * x[:, 1],
                     x[:, 0] - x[:, 1] ** 2], 1)


def _fitted_post(n: int = 30, seed: int = 0, steps: int = 120):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-2, 2, (n, 2)).astype(np.float32)
    return gp_lib.fit(x, _target(x).astype(np.float32), steps=steps), rng


def _stream(rng, n_batches: int, sizes=(1, 2, 3, 5)):
    """Seeded conditioning stream of varying batch widths."""
    for b in range(n_batches):
        k = sizes[b % len(sizes)]
        x = rng.uniform(-2, 2, (k, 2)).astype(np.float32)
        yield x, _target(x).astype(np.float32)


PROBE = np.stack(np.meshgrid(np.linspace(-2, 2, 7),
                             np.linspace(-2, 2, 7),
                             indexing="ij"), -1).reshape(-1, 2)
PROBE = PROBE.astype(np.float32)


def _assert_close_scaled(got, want, tol=1e-3):
    """Per-output-column agreement within `tol` of that column's range
    (plus a small absolute floor).  Both engines run the same math in
    f32 through different backends (LAPACK vs XLA), so the honest pin
    is uncorrelated-rounding-sized relative to the signal, not machine
    epsilon; 1e-3 of range would still catch any algorithmic drift."""
    got, want = np.asarray(got), np.asarray(want)
    scale = want.max(axis=0) - want.min(axis=0)
    err = np.abs(got - want).max(axis=0)
    assert (err <= 5e-4 + tol * np.maximum(scale, 1.0)).all(), \
        f"err={err} vs scale={scale}"


# ---------------------------------------------------------------------------
# incremental == exact: the differential contract
# ---------------------------------------------------------------------------
def test_incremental_matches_exact_over_long_stream():
    """Rank-k block updates must be numerically indistinguishable from
    full refactorisation — checked after EVERY batch of a 16-batch
    stream, not just at the end."""
    post, rng = _fitted_post()
    exact = engine_lib.wrap_posterior(post, "exact")
    inc = engine_lib.wrap_posterior(post, "incremental")
    for x, y in _stream(rng, 16):
        exact = exact.condition(x, y)
        inc = inc.condition(x, y)
        me, ve = exact.predict_batch(PROBE)
        mi, vi = inc.predict_batch(PROBE)
        _assert_close_scaled(mi, me)
        _assert_close_scaled(vi, ve)
        assert inc.n_train() == exact.n_train()
    # the stream actually exercised the block-update path
    assert inc.stats["block_updates"] >= 14


def test_incremental_periodic_refactor_still_matches():
    post, rng = _fitted_post(seed=1)
    exact = engine_lib.wrap_posterior(post, "exact")
    inc = engine_lib.wrap_posterior(post, "incremental",
                                    refactor_every=3)
    for x, y in _stream(rng, 10):
        exact = exact.condition(x, y)
        inc = inc.condition(x, y)
    assert inc.stats["refactors"] >= 3         # hygiene path taken
    me, _ = exact.predict_batch(PROBE)
    mi, _ = inc.predict_batch(PROBE)
    _assert_close_scaled(mi, me)


def test_incremental_recency_window_matches_exact():
    """A sliding `max_points` window must keep both backends on the SAME
    most-recent subset (the window slide forces a refactor)."""
    post, rng = _fitted_post(seed=2)
    exact = engine_lib.wrap_posterior(post, "exact", max_points=40)
    inc = engine_lib.wrap_posterior(post, "incremental", max_points=40)
    for x, y in _stream(rng, 12):
        exact = exact.condition(x, y)
        inc = inc.condition(x, y)
    assert exact.n_train() <= 40 and inc.n_train() == exact.n_train()
    np.testing.assert_allclose(np.asarray(inc.x), np.asarray(exact.x))
    me, _ = exact.predict_batch(PROBE)
    mi, _ = inc.predict_batch(PROBE)
    _assert_close_scaled(mi, me)


def test_incremental_maintains_linv_invariant():
    """After a block update the cached inverse factor must still BE the
    inverse of the extended Cholesky — the whole point of extending it
    instead of re-inverting (O(n³)) or serving a stale one (wrong)."""
    post, rng = _fitted_post(seed=3)
    inc = engine_lib.wrap_posterior(post, "incremental")
    for x, y in _stream(rng, 4):
        inc = inc.condition(x, y)
    assert inc.stats["block_updates"] >= 4
    n = inc.n_train()
    prod = np.asarray(inc.post.linv) @ np.asarray(inc.post.chol)
    np.testing.assert_allclose(prod, np.eye(n), atol=2e-3)


# ---------------------------------------------------------------------------
# partitioned: bounded error, bounded experts
# ---------------------------------------------------------------------------
def test_partitioned_error_bounded_vs_exact():
    rng = np.random.default_rng(4)
    x = rng.uniform(-2, 2, (200, 2)).astype(np.float32)
    y = _target(x).astype(np.float32)
    post = gp_lib.fit(x, y, steps=150)
    exact = engine_lib.wrap_posterior(post, "exact")
    part = engine_lib.wrap_posterior(post, "partitioned", expert_cap=64)
    me, _ = exact.predict_batch(PROBE)
    mp, _ = part.predict_batch(PROBE)
    me, mp = np.asarray(me), np.asarray(mp)
    rng_y = me.max(axis=0) - me.min(axis=0)
    err = np.abs(mp - me)
    # local experts approximate: bound mean and worst-case error
    # relative to the exact posterior's output range
    assert (err.mean(axis=0) <= 0.10 * rng_y).all()
    assert (err.max(axis=0) <= 0.35 * rng_y).all()


def test_partitioned_single_expert_is_exact():
    """With everything in one expert the ensemble IS an exact GP under
    the frozen fit-time standardisation — zero approximation."""
    post, _ = _fitted_post(seed=5)
    exact = engine_lib.wrap_posterior(post, "exact")
    part = engine_lib.wrap_posterior(post, "partitioned",
                                     expert_cap=1000)
    assert len(part.experts) == 1
    me, ve = exact.predict_batch(PROBE)
    mp, vp = part.predict_batch(PROBE)
    np.testing.assert_allclose(np.asarray(mp), np.asarray(me),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(vp), np.asarray(ve),
                               atol=2e-4, rtol=1e-3)


def test_partitioned_condition_keeps_cap_and_splits():
    post, rng = _fitted_post(n=20, seed=6)
    part = engine_lib.wrap_posterior(post, "partitioned", expert_cap=16)
    total = part.n_train()
    for x, y in _stream(rng, 10, sizes=(7,)):
        part = part.condition(x, y)
        total += 7
    assert part.n_train() == total             # no point ever dropped
    assert all(int(e.x.shape[0]) <= 16 for e in part.experts)
    assert part.stats["splits"] >= 1
    mean, var = part.predict_batch(PROBE)
    assert np.isfinite(np.asarray(mean)).all()
    assert (np.asarray(var) > 0).all()


def test_partitioned_condition_is_persistent():
    """Conditioning returns a NEW engine; the old generation must keep
    answering from its own (cached) operands."""
    post, rng = _fitted_post(seed=7)
    part = engine_lib.wrap_posterior(post, "partitioned", expert_cap=16)
    before, _ = part.predict_batch(PROBE)
    x, y = next(_stream(rng, 1))
    part2 = part.condition(x, y)
    assert part2 is not part
    again, _ = part.predict_batch(PROBE)
    np.testing.assert_array_equal(np.asarray(before), np.asarray(again))
    assert part2.n_train() == part.n_train() + len(x)


# ---------------------------------------------------------------------------
# fused multi-expert predict: padding exactness + dispatch parity
# ---------------------------------------------------------------------------
def _expert_operands(seed=8, n1=12, n2=7):
    """Two different-size experts stacked with zero padding."""
    post, rng = _fitted_post(seed=seed)
    part = engine_lib.wrap_posterior(post, "partitioned", expert_cap=16)
    xs = rng.uniform(-2, 2, (2, 5, 2)).astype(np.float32)
    xt, al, li = part._stacked()
    ls = jnp.exp(jnp.clip(post.params.log_lengthscale, -5.0, 5.0))
    var = jnp.exp(jnp.clip(post.params.log_variance, -8.0, 8.0))
    return part, xs, xt, al, li, ls, var


def test_gp_predict_experts_matches_per_expert_reference():
    part, xs, xt, al, li, ls, var = _expert_operands()
    mean, qf = kops.gp_predict_experts(xt, jnp.asarray(xs), ls, var,
                                       al, li, part.kind)
    assert mean.shape == (len(part.experts), 5, part.n_outputs())
    # per-expert single-GP reference on the UNPADDED operands: padded
    # training rows (alpha = 0, linv rows/cols = 0) must be exact no-ops
    for e, ex in enumerate(part.experts):
        n = int(ex.x.shape[0])
        m1, q1 = kref.gp_predict(ex.x, jnp.asarray(xs[e]), ls, var,
                                 ex.alpha, ex.linv, part.kind)
        np.testing.assert_allclose(np.asarray(mean[e]), np.asarray(m1),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(qf[e]),
                                   np.asarray(q1).reshape(-1),
                                   atol=1e-5, rtol=1e-5)


def test_gp_predict_experts_ops_dispatch():
    part, xs, xt, al, li, ls, var = _expert_operands(seed=9)
    m_def, q_def = kops.gp_predict_experts(xt, jnp.asarray(xs), ls, var,
                                           al, li, part.kind)
    m_ref, q_ref = kops.gp_predict_experts(xt, jnp.asarray(xs), ls, var,
                                           al, li, part.kind, impl="ref")
    np.testing.assert_allclose(np.asarray(m_def), np.asarray(m_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(q_def), np.asarray(q_ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# bucket discipline: bounded compile shapes on every backend
# ---------------------------------------------------------------------------
def test_bucket_discipline_unchanged_on_exact():
    post, _ = _fitted_post(seed=10)
    eng = engine_lib.wrap_posterior(post, "exact")
    gp_lib.predict_batch_shapes.clear()
    for s in (3, 17, 63, 65, 200):
        eng.predict_batch(PROBE[:s])
    widths = {k[-1] for k in gp_lib.predict_batch_shapes}
    assert widths <= set(gp_lib.PREDICT_BUCKETS)


def test_bucket_discipline_partitioned():
    """Partitioned predicts log ("part", E, n_stack, bucket) keys — the
    per-(ensemble shape) compile bill stays len(PREDICT_BUCKETS)."""
    post, _ = _fitted_post(seed=11)
    part = engine_lib.wrap_posterior(post, "partitioned", expert_cap=16)
    gp_lib.predict_batch_shapes.clear()
    for s in (1, 5, 30, 49):
        part.predict_batch(PROBE[:s])
    keys = [k for k in gp_lib.predict_batch_shapes if k[0] == "part"]
    assert keys and all(k[1] == len(part.experts) for k in keys)
    assert {k[-1] for k in keys} <= set(gp_lib.PREDICT_BUCKETS)


# ---------------------------------------------------------------------------
# linv staleness contract (cached-inverse audit)
# ---------------------------------------------------------------------------
def test_linv_contract_fresh_after_condition():
    """Every update path must yield a posterior whose cached linv (if
    any) inverts ITS chol — a stale carry-over from the pre-update
    posterior would silently corrupt predict_batch variances."""
    post, rng = _fitted_post(seed=12)
    gp_lib.ensure_linv(post)
    for backend in ("exact", "incremental"):
        eng = engine_lib.wrap_posterior(post, backend)
        x, y = next(_stream(rng, 1))
        new = eng.condition(x, y)
        p = new.post
        assert p is not post
        if p.linv is not None:
            n = int(p.x.shape[0])
            np.testing.assert_allclose(
                np.asarray(p.linv) @ np.asarray(p.chol), np.eye(n),
                atol=2e-3)


def test_invalidate_linv_forces_recompute():
    post, _ = _fitted_post(seed=13)
    m0, v0 = gp_lib.predict_batch(post, PROBE[:5])   # populates linv
    assert post.linv is not None
    gp_lib.invalidate_linv(post)
    assert post.linv is None
    m1, v1 = gp_lib.predict_batch(post, PROBE[:5])   # recomputes
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m0),
                               atol=1e-6)
    assert post.linv is not None


def test_stale_linv_would_be_wrong_guard():
    """The audit that motivates the contract: grafting posterior A's
    linv onto conditioned posterior B produces measurably wrong
    variances — proving no in-tree path may ever reuse a factor."""
    post, rng = _fitted_post(seed=14)
    gp_lib.ensure_linv(post)
    x, y = next(_stream(rng, 1))
    eng = engine_lib.wrap_posterior(post, "exact").condition(x, y)
    good = np.asarray(eng.predict_batch(PROBE[:9])[1])
    forged = gp_lib.GPPosterior(
        params=eng.post.params, x=eng.post.x, y=eng.post.y,
        y_mean=eng.post.y_mean, y_std=eng.post.y_std,
        chol=eng.post.chol, alpha=eng.post.alpha, kind=eng.post.kind,
        linv=jnp.pad(post.linv, ((0, 1), (0, 1))))   # stale, padded
    bad = np.asarray(gp_lib.predict_batch(forged, PROBE[:9])[1])
    assert not np.allclose(bad, good, atol=1e-5)


# ---------------------------------------------------------------------------
# consumers run on every backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", engine_lib.BACKENDS)
def test_offload_router_on_backend(backend):
    rng = np.random.default_rng(15)
    x = rng.uniform(-2, 2, (40, 2)).astype(np.float32)
    post = gp_lib.fit(x, _target(x).astype(np.float32), steps=120)
    sur = SurrogateOffload(post, backend=backend, sd_threshold=0.3,
                           condition_every=4)
    sds = sur.trust_sd(PROBE[:10].tolist())
    assert sds.shape == (10,) and np.isfinite(sds).all()
    n0 = sur._engine.n_train()
    for i in range(8):                          # batches of condition_every
        theta = rng.uniform(-2, 2, 2).astype(np.float32)
        sur.observe(theta.tolist(),
                    _target(theta[None])[0].tolist())
    assert sur._engine.n_train() > n0           # stream absorbed
    req = EvalRequest("m", [PROBE[0].tolist()], time_request=30.0)
    assert sur.decide(req, cost=30.0) in (True, False)


@pytest.mark.parametrize("name,backend", [("gp", "exact"),
                                          ("gp-incremental", "incremental"),
                                          ("gp-partitioned", "partitioned")])
def test_predictor_registry_backends(name, backend):
    pred = make_predictor(name)
    assert isinstance(pred, GPRuntimePredictor)
    assert pred.backend == backend
    rng = np.random.default_rng(16)
    for _ in range(24):
        z = float(rng.uniform(0.1, 2.0))
        req = EvalRequest("m", [[z, z / 2]])
        pred.observe(req, 0.5 + z)              # runtime grows with z
    assert pred._post is not None
    req = EvalRequest("m", [[1.0, 0.5]])
    p = pred.predict(req)
    assert p is not None and 0.0 < p < 60.0
    many = pred.predict_many([req] * 3)
    assert all(abs(m - p) < 1e-6 for m in many)
    (mean, sd), = pred.predict_many_with_sd([req])
    assert mean == pytest.approx(p) and sd >= 0.0


def _quad_factory():
    return LambdaModel("quad",
                       lambda x: (float(x[0] ** 2 + x[1]),
                                  float(x[0] - x[1] ** 2)), 2, 2)


@pytest.mark.parametrize("backend", engine_lib.BACKENDS)
def test_adaptive_stream_on_backend(backend):
    rng = np.random.default_rng(17)
    x = rng.uniform(-2, 2, (40, 2)).astype(np.float32)
    y = np.stack([x[:, 0] ** 2 + x[:, 1], x[:, 0] - x[:, 1] ** 2], 1)
    post = gp_lib.fit(x, y.astype(np.float32), steps=150)
    probe = rng.uniform(-1.5, 1.5, (8, 2)).astype(np.float32)
    with Executor({"quad": _quad_factory}, n_workers=2) as ex:
        res = adaptive.evaluate_stream(ex, "quad", post, probe,
                                       sd_threshold=0.25,
                                       backend=backend)
    want = np.stack([probe[:, 0] ** 2 + probe[:, 1],
                     probe[:, 0] - probe[:, 1] ** 2], 1)
    np.testing.assert_allclose(res.outputs, want, atol=0.5)
    if backend in ("exact", "incremental"):
        assert isinstance(res.posterior, gp_lib.GPPosterior)


@pytest.mark.parametrize("backend", ["exact", "incremental"])
def test_bayesian_quadrature_backend_agrees(backend):
    def model(x):
        return float(np.sin(x[6])), 0.2

    base = np.zeros(7)
    res = qoi.bayesian_quadrature(model, base, n_init=5, n_adaptive=5,
                                  candidate_grid=8, backend=backend)
    assert np.isfinite(res.value) and res.n_evals == 10
    if backend == "incremental":
        ref = qoi.bayesian_quadrature(model, base, n_init=5,
                                      n_adaptive=5, candidate_grid=8,
                                      backend="exact")
        # identical seeds + matching engines -> the same node choices
        assert res.value == pytest.approx(ref.value, abs=1e-3)


# ---------------------------------------------------------------------------
# uncertainty-aware packing (risk_lambda)
# ---------------------------------------------------------------------------
class _FakeSDPredictor:
    """predict_many_with_sd stub: runtime = first param, sd = second."""

    def predict(self, req):
        return float(req.parameters[0][0])

    def predict_many(self, reqs):
        return [self.predict(r) for r in reqs]

    def predict_many_with_sd(self, reqs):
        return [(float(r.parameters[0][0]), float(r.parameters[0][1]))
                for r in reqs]


def test_pack_risk_lambda_zero_is_reference():
    """λ=0 must leave the mean-only path untouched (never even calls
    the sd hook)."""

    class Exploding(_FakeSDPredictor):
        def predict_many_with_sd(self, reqs):
            raise AssertionError("sd hook must not run at lambda=0")

    pol = PackingPolicy(predictor=Exploding())
    req = EvalRequest("m", [[7.0, 3.0]])
    assert pol.cost(req) == 7.0
    assert pol.costs([req]) == [7.0]


def test_pack_risk_lambda_inflates_uncertain_costs():
    pol = PackingPolicy(predictor=_FakeSDPredictor(), risk_lambda=2.0)
    certain = EvalRequest("m", [[10.0, 0.0]])
    uncertain = EvalRequest("m", [[10.0, 4.0]])
    assert pol.cost(certain) == 10.0
    assert pol.cost(uncertain) == pytest.approx(18.0)
    assert pol.costs([certain, uncertain]) == [10.0, 18.0]


def test_pack_risk_lambda_budget_fit_prefers_certain_task():
    """Two tasks with equal mean runtime: under a tight remaining
    budget, the risk-adjusted key must stop the uncertain one from
    being packed as if it were certain."""
    from repro.sched.policy import WorkerView
    pol = PackingPolicy(predictor=_FakeSDPredictor(), risk_lambda=2.0)
    certain = EvalRequest("m", [[10.0, 0.0]])
    uncertain = EvalRequest("m", [[10.0, 4.0]])
    pol.push(uncertain, 0)
    pol.push(certain, 0)
    # remaining budget fits 10s + margin but not the risk-adjusted 18s
    got, _ = pol.pop(WorkerView(budget_left=12.0))
    assert got is certain


def test_pack_risk_lambda_falls_back_without_estimate():
    class NonePredictor(_FakeSDPredictor):
        def predict_many_with_sd(self, reqs):
            return [(None, None)] * len(reqs)

    pol = PackingPolicy(predictor=NonePredictor(), risk_lambda=1.0)
    req = EvalRequest("m", [[1.0, 1.0]], time_request=42.0)
    assert pol.cost(req) == 42.0


def test_quantile_estimator_sd_proxy():
    est = QuantileEstimator(min_observed=3)
    for s in (1.0, 2.0, 3.0, 4.0, 5.0):
        est.observe(EvalRequest("m", [[0.0]]), s)
    (mean, sd), = est.predict_many_with_sd([EvalRequest("m", [[0.0]])])
    assert mean == pytest.approx(3.0)
    assert sd > 0.0
    # unseen model: no estimate, not a crash
    (m2, s2), = est.predict_many_with_sd([EvalRequest("zz", [[0.0]])])
    assert m2 is None and s2 is None


# ---------------------------------------------------------------------------
# factories / interface
# ---------------------------------------------------------------------------
def test_factories_and_protocol():
    post, _ = _fitted_post(n=16, seed=18, steps=40)
    for b in engine_lib.BACKENDS:
        eng = engine_lib.wrap_posterior(post, b)
        assert isinstance(eng, engine_lib.SurrogateEngine)
        assert eng.backend == b
        assert eng.dim() == 2 and eng.n_outputs() == 2
        again = engine_lib.as_engine(eng, "exact")
        assert again is eng                     # engines pass through
    assert engine_lib.as_engine(None) is None
    with pytest.raises(ValueError, match="unknown surrogate backend"):
        engine_lib.wrap_posterior(post, "bogus")


def test_fit_engine_each_backend():
    rng = np.random.default_rng(19)
    x = rng.uniform(-2, 2, (40, 2)).astype(np.float32)
    y = _target(x).astype(np.float32)
    for b in engine_lib.BACKENDS:
        eng = engine_lib.fit_engine(x, y, b, steps=40)
        assert eng.backend == b and eng.n_train() == 40
        mean, var = eng.predict_batch(PROBE[:6])
        assert np.isfinite(np.asarray(mean)).all()
        assert (np.asarray(var) > 0).all()
        sds = eng.latent_sd(PROBE[:6])
        assert sds.shape == (6,) and (sds >= 0).all()
