"""Benchmark: scheduling-policy comparison on UQ-shaped workloads.

Runs every registered `repro.sched` policy against the paper's two backend
mechanisms (per-job SLURM, bulk-allocation HQ) on the two runtime
distributions the paper says make UQ scheduling hard:

  * bimodal   — mostly-short tasks with a long-running minority (the
                "minutes to hours" GS2 spread collapsed to two modes);
  * heavy-tailed — lognormal runtimes with a long right tail.

Emits one row per (workload, backend, policy) with makespan / SLR /
scheduling-overhead statistics over several seeds, plus derived headline
numbers (cost-aware packing vs FCFS).  Everything is seeded: repeated runs
produce identical tables.  Cost-aware policies see per-task time-request
hints (the HQ hint, here oracle-accurate); `pack+quantile` rows instead
learn per-model costs online from completions only — the predictor
value-add, no hints required.

CI-feasible: pure-python discrete-event simulation, < 5 s end to end.

    PYTHONPATH=src python benchmarks/policy_comparison.py
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core import backends, metrics, simulate_policy
from repro.core.simulator import Workload

SEEDS = (3, 7, 13)
N_WORKERS = 4
POLICY_ROWS: Tuple[Tuple[str, str, str], ...] = (
    # (row label, policy name, hints mode; None = online predictor only)
    ("fcfs", "fcfs", "workload"),
    ("sjf", "sjf", "oracle"),
    ("lpt", "lpt", "oracle"),
    ("pack", "pack", "oracle"),
    ("steal", "steal", "workload"),
    ("pack+quantile", "pack", None),           # learns costs online
)
BACKEND_NAMES = ("slurm", "hq")


def bimodal_workload(n: int = 60, seed: int = 0, short: float = 2.0,
                     long: float = 40.0, frac_long: float = 0.2
                     ) -> Tuple[Workload, List[str]]:
    """Bimodal runtimes from a two-model campaign (a cheap surrogate and
    an expensive simulator) — per-task model names let per-model
    predictors and locality-aware policies discriminate."""
    rng = np.random.default_rng(seed)
    n_long = max(int(round(frac_long * n)), 1)
    rts = np.array([long] * n_long + [short] * (n - n_long))
    names = np.array(["long-model"] * n_long + ["short-model"] * (n - n_long))
    rts *= np.exp(0.05 * rng.standard_normal(n))     # hardware jitter
    order = rng.permutation(n)
    rts, names = rts[order], names[order]
    w = Workload(name="bimodal", runtimes=tuple(float(r) for r in rts),
                 slurm_alloc=120.0, hq_alloc=900.0,
                 time_request=60.0, time_limit=300.0)
    return w, [str(s) for s in names]


def heavy_tailed_workload(n: int = 60, seed: int = 0,
                          median: float = 4.0, sigma: float = 1.2
                          ) -> Tuple[Workload, None]:
    rng = np.random.default_rng(seed)
    rts = median * np.exp(sigma * rng.standard_normal(n))
    w = Workload(name="heavy-tail",
                 runtimes=tuple(float(r) for r in rts),
                 slurm_alloc=300.0, hq_alloc=1800.0,
                 time_request=60.0, time_limit=600.0)
    return w, None


def run(n_workers: int = N_WORKERS, seeds: Tuple[int, ...] = SEEDS
        ) -> List[Dict]:
    rows: List[Dict] = []
    for wname, make_w in (("bimodal", bimodal_workload),
                          ("heavy-tail", heavy_tailed_workload)):
        for backend in BACKEND_NAMES:
            spec = backends.get(backend)
            for label, policy, hints in POLICY_ROWS:
                predictor = "quantile" if hints is None else None
                mk, slr_v, ovh = [], [], []
                for seed in seeds:
                    w, names = make_w(seed=seed)
                    recs = simulate_policy(
                        spec, w, n_workers=n_workers, policy=policy,
                        predictor=predictor, seed=seed, hints=hints,
                        model_names=names)
                    s = metrics.summarize(wname, f"{backend}/{label}", recs)
                    mk.append(s.makespan)
                    slr_v.append(s.slr)
                    ovh.append(s.overhead_stats["median"])
                rows.append({
                    "workload": wname, "backend": backend, "policy": label,
                    "makespan_mean": float(np.mean(mk)),
                    "makespan_std": float(np.std(mk)),
                    "slr_mean": float(np.mean(slr_v)),
                    "overhead_median": float(np.mean(ovh)),
                })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    """Headline numbers: what cost-aware dispatch buys over FCFS."""
    by = {(r["workload"], r["backend"], r["policy"]): r for r in rows}

    def reduction(workload: str, backend: str, policy: str) -> float:
        base = by[(workload, backend, "fcfs")]["makespan_mean"]
        cand = by[(workload, backend, policy)]["makespan_mean"]
        return 1.0 - cand / base

    return {
        "bimodal_hq_pack_vs_fcfs": reduction("bimodal", "hq", "pack"),
        "bimodal_hq_pack_quantile_vs_fcfs":
            reduction("bimodal", "hq", "pack+quantile"),
        "heavy_tail_hq_pack_vs_fcfs": reduction("heavy-tail", "hq", "pack"),
        "heavy_tail_slurm_pack_vs_fcfs":
            reduction("heavy-tail", "slurm", "pack"),
    }


def main():
    rows = run()
    cols = ("workload", "backend", "policy", "makespan_mean",
            "makespan_std", "slr_mean", "overhead_median")
    print("| " + " | ".join(cols) + " |")
    print("|" + "|".join("---" for _ in cols) + "|")
    for r in rows:
        print("| " + " | ".join(
            f"{r[c]:.2f}" if isinstance(r[c], float) else str(r[c])
            for c in cols) + " |")
    print()
    for k, v in derived(rows).items():
        print(f"{k}: {v:+.1%}")


if __name__ == "__main__":
    main()
