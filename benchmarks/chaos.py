"""Benchmark: deterministic fault injection + hardened recovery gates.

Sweeps seeded `FaultPlan` intensities (none / low / med / high) over an
elastic autoalloc scenario with retry backoff, poison-task quarantine
and speculative re-execution enabled, and gates CI on the recovery
contract the `repro.chaos` subsystem promises:

  * **parity** — `run_parity` with every faulted plan stays EXACT: the
    sim and live-replay drivers observe identical fault sequences and
    produce identical records, allocation events, billing and span
    sequences (zero divergences);
  * **invariants** — `InvariantChecker` reports zero violations on both
    drivers at every intensity: exactly one terminal state per task,
    node-second billing additive across crashes / preemptions /
    speculation, no orphaned workers, allocations closed;
  * **no lost tasks** — every submitted task reaches a terminal record
    at every intensity (crash-requeue, preemption-migrate and backoff
    machinery never drop work; quarantine is a deliberate terminal
    state, not loss);
  * **bounded recovery overhead** — the faulted makespan stays within
    ``MAX_MAKESPAN_PENALTY`` of the fault-free baseline (recovery
    works by re-execution, not by waiting out the horizon).

Writes ``BENCH_chaos.json`` (per-intensity fault mix, outcome counts,
makespan penalty, invariant measures); non-zero exit on any gate
failure.

    PYTHONPATH=src python benchmarks/chaos.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List

from repro.chaos import FaultEvent, FaultPlan, InvariantChecker
from repro.cluster import AutoAllocConfig, TraceTask, bursty_trace
from repro.cluster.parity import run_parity
from repro.core import backends
from repro.core.task import RetryPolicy
from repro.obs import Tracer

# recovery must beat re-submission: a faulted sweep whose makespan
# exceeds the fault-free baseline by more than this fraction fails CI
MAX_MAKESPAN_PENALTY = 1.5

# expected fault events per 600 s horizon, scaled per intensity
_RATE_UNIT = {
    "worker_crash": 2.0, "preempt": 1.0, "slow_node": 1.0,
    "corrupt_result": 1.0, "surrogate_outage": 1.0,
}
INTENSITIES = {"none": 0.0, "low": 1.0, "med": 2.0, "high": 4.0}


def _cfg() -> AutoAllocConfig:
    return AutoAllocConfig(workers_per_alloc=2, walltime_s=300.0,
                           backlog_high_s=10.0, backlog_low_s=2.0,
                           max_pending=3, max_allocations=6,
                           min_allocations=1, idle_drain_s=30.0,
                           hysteresis_s=5.0)


def _plan(intensity: float, seed: int, horizon_s: float) -> FaultPlan:
    if intensity <= 0.0:
        return FaultPlan()
    rates = {k: v * intensity / 600.0 for k, v in _RATE_UNIT.items()}
    return FaultPlan.generate(seed=seed, horizon_s=horizon_s,
                              rates=rates, grace_s=60.0,
                              slow_factor=3.0, slow_duration_s=120.0,
                              outage_s=120.0)


def run_intensity(name: str, intensity: float, trace, *,
                  seed: int, horizon_s: float,
                  plan: FaultPlan = None,
                  retry: RetryPolicy = None,
                  max_attempts: int = 8) -> Dict[str, Any]:
    spec = backends.get("hq")
    if plan is None:
        plan = _plan(intensity, seed=seed + 17, horizon_s=horizon_s)
    if retry is None:
        retry = RetryPolicy(base_s=2.0, factor=2.0, max_s=30.0,
                            jitter=0.5, quarantine_after=4)
    sim_tr, live_tr = Tracer(capacity=262_144), Tracer(capacity=262_144)

    t0 = time.perf_counter()
    rep = run_parity(spec, trace, autoalloc=_cfg(), max_workers=12,
                     max_attempts=max_attempts, seed=seed,
                     fault_plan=plan, retry_policy=retry,
                     straggler_factor=4.0, straggler_min_completed=5,
                     tracers=(sim_tr, live_tr))
    wall = time.perf_counter() - t0

    problems: List[str] = []
    if not rep.ok:
        problems += [f"{name}: parity diverged: {d}"
                     for d in rep.divergences[:8]]

    expected = [f"trace-{i}" for i in range(len(trace))]
    checker = InvariantChecker()
    measures: Dict[str, Dict[str, float]] = {}
    for side, res, tr in (("sim", rep.sim, sim_tr),
                          ("live", rep.live, live_tr)):
        inv = checker.check(records=res.records,
                            allocations=res.allocations,
                            events=tr.events(),
                            expected_tasks=expected)
        measures[side] = inv.measures
        problems += [f"{name}/{side}: {v}" for v in inv.violations]

    by_status: Dict[str, int] = {}
    for r in rep.sim.records:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    lost = [t for t in expected
            if t not in {r.task_id for r in rep.sim.records}]
    lost += [r.task_id for r in rep.sim.records if r.status == "lost"]
    if lost:
        problems.append(f"{name}: {len(lost)} lost tasks: "
                        f"{sorted(lost)[:5]}")

    summary = rep.sim.summary()
    fired = [e for e in sim_tr.events() if e[2] == "chaos.fire"]
    recovery = {k: sum(1 for e in sim_tr.events() if e[2] == f"task.{k}")
                for k in ("requeue", "migrate", "speculate",
                          "hedge_cancel", "quarantined")}
    mix = ", ".join(f"{k}x{v}" for k, v in plan.kinds().items()) or "clean"
    print(f"[{name:<4}] {len(plan)} faults ({mix}), "
          f"{by_status} makespan {summary['makespan']:.1f}s "
          f"node-s {summary['node_seconds']:.0f} "
          f"parity={'OK' if rep.ok else 'DIVERGED'} "
          f"({wall*1e3:.0f} ms)")

    return {
        "intensity": name,
        "scale": intensity,
        "fault_mix": plan.kinds(),
        "n_faults_planned": len(plan),
        "n_faults_fired": len(fired),
        "by_status": by_status,
        "n_lost": len(lost),
        "recovery_actions": recovery,
        "makespan_s": summary["makespan"],
        "node_seconds": summary["node_seconds"],
        "n_allocations": summary["n_allocations"],
        "parity_ok": rep.ok,
        "n_divergences": len(rep.divergences),
        "invariant_measures": measures,
        "wall_s": wall,
        "problems": problems,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller trace, fewer seeds")
    ap.add_argument("--json", default="BENCH_chaos.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    bursts, size = (2, 8) if args.quick else (3, 14)
    trace = bursty_trace(n_bursts=bursts, burst_size=size,
                         seed=args.seed + 1)
    horizon_s = 1200.0

    results = [run_intensity(name, scale, trace, seed=args.seed,
                             horizon_s=horizon_s)
               for name, scale in INTENSITIES.items()]

    # targeted scenario: crash + preemption-with-migration + result
    # corruption + straggler hedging in ONE faulted parity run — every
    # recovery path fires and both drivers must still agree exactly
    target_trace = [TraceTask(t=i * 0.5, runtime=2.0) for i in range(14)]
    target_trace += [TraceTask(t=7.0, runtime=120.0),
                     TraceTask(t=7.5, runtime=90.0)]
    targeted = run_intensity(
        "targeted", 0.0, target_trace, seed=5, horizon_s=horizon_s,
        max_attempts=6,
        plan=FaultPlan(events=(
            FaultEvent(t=12.0, kind="worker_crash", target=1),
            FaultEvent(t=20.0, kind="preempt", target=0, duration_s=15.0),
            FaultEvent(t=31.0, kind="corrupt_result", target=0),
        )),
        retry=RetryPolicy(base_s=1.0, factor=2.0, max_s=20.0, jitter=0.3,
                          quarantine_after=3))
    ra = targeted["recovery_actions"]
    for action in ("requeue", "migrate", "speculate", "hedge_cancel"):
        if ra.get(action, 0) <= 0:
            targeted["problems"].append(
                f"targeted: recovery path {action!r} never fired")
    results.append(targeted)

    problems = [p for r in results for p in r["problems"]]

    baseline = next(r for r in results if r["intensity"] == "none")
    for r in results:
        if r["intensity"] == "targeted":     # different trace: no penalty
            continue
        r["makespan_penalty"] = (r["makespan_s"] / baseline["makespan_s"]
                                 - 1.0) if baseline["makespan_s"] else 0.0
        if r["makespan_penalty"] > MAX_MAKESPAN_PENALTY:
            problems.append(
                f"{r['intensity']}: makespan penalty "
                f"{r['makespan_penalty']:.2f} exceeds bound "
                f"{MAX_MAKESPAN_PENALTY}")
    if not any(r["n_faults_fired"] for r in results):
        problems.append("sweep fired zero faults: intensities degenerate")

    print("\nrecovery overhead vs clean baseline "
          f"(makespan {baseline['makespan_s']:.1f}s):")
    for r in results:
        pen = (f"{r['makespan_penalty']*100:+6.1f}%"
               if "makespan_penalty" in r else "   n/a")
        print(f"  {r['intensity']:<8} penalty {pen}  "
              f"node-s {r['node_seconds']:.0f}  "
              f"recovery {r['recovery_actions']}")

    out = {"bench": "chaos", "quick": bool(args.quick),
           "seed": args.seed,
           "max_makespan_penalty": MAX_MAKESPAN_PENALTY,
           "intensities": results, "problems": problems}
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    if problems:
        print("\nFAIL:")
        for p in problems:
            print(f"  {p}")
        return 1
    print("\nall chaos gates PASS (parity exact, zero invariant "
          "violations, zero lost tasks, recovery bounded)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
