"""Benchmark: trace-driven calibration closes the sim-to-reality gap.

Records a live-executor run (sleep-backed models with real thread
scheduling, real warmup costs, real ~0 dispatch latency) into a JSONL
trace, then asks: how well does `simulate_cluster` reproduce the live
run's per-phase overhead attribution when replaying the same workload —
first with the uncalibrated paper-constant `BackendSpec` ("hq": 1 s
server init, 8 ms dispatch, HPC-queue wait model), then with the
`CalibratedBackendSpec` fitted from the very trace under test?

Reported per spec: the `repro.obs.attribute_overhead` totals over the
replayed sim trace, and the phase-wise attribution error vs live —
``sum_phases |sim_total - live_total| / n_tasks`` over queue_wait /
alloc_wait / dispatch / retry / init.  Gates (exit 1, enforced in CI):

  * calibrated error STRICTLY below uncalibrated error;
  * round-trip identity: a sim-recorded trace replayed through
    `TraceReplay` reproduces the original records and makespan EXACTLY
    (bitwise — the `repro.obs.replay` contract);
  * drift: a `CalibrationMonitor` over the uncalibrated spec raises
    alarms on the live trace, the calibrated one stays silent.

``--quick`` skips the live recording and runs the same pipeline on the
committed sample trace (`benchmarks/data/sample_live_trace.jsonl`) — the
CI calibration-smoke job.  ``--trace-out`` keeps the recorded live trace
(that is how the committed sample was produced).

Usage:
    python benchmarks/calibration.py [--quick] [--out BENCH_calibration.json]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import tempfile
import time

import numpy as np

from repro.cluster.autoalloc import AutoAllocConfig
from repro.cluster.sim import simulate_cluster
from repro.cluster.traces import bursty_trace
from repro.core import EvalRequest, Executor, LambdaModel, backends
from repro.obs import (CalibrationMonitor, TraceReplay, Tracer,
                       attribute_overhead, calibrate, read_jsonl)

PHASE_KEYS = ("queue_wait_s", "alloc_wait_s", "dispatch_s", "retry_s",
              "init_s")
SAMPLE_TRACE = os.path.join(os.path.dirname(__file__), "data",
                            "sample_live_trace.jsonl")


# ---------------------------------------------------------------------------
# live recording: sleep-backed models through the real threaded executor
# ---------------------------------------------------------------------------
def _sleep_model(name: str, warmup_s: float):
    """A model whose compute is exactly its first parameter (seconds of
    sleep) and whose server warmup really costs `warmup_s` — so the
    recorded trace carries known-true runtimes and init costs."""

    def fn(parameters, config):
        time.sleep(parameters[0][0])
        return [[float(parameters[0][0])]]

    return LambdaModel(name, fn, 1, 1,
                       warmup_fn=lambda: time.sleep(warmup_s))


def record_live_trace(path: str, *, n_tasks: int = 24, n_workers: int = 3,
                      seed: int = 7) -> list:
    """One seeded live run, burst-submitted, streamed to `path` while it
    runs (`stream_to` is the crash-safe recording mode); returns the
    events re-loaded through `read_jsonl` — the same ingestion route a
    real cluster log would take."""
    rng = np.random.default_rng(seed)
    base = time.monotonic()
    tracer = Tracer().stream_to(path)
    factories = {"fast": lambda: _sleep_model("fast", 0.01),
                 "slow": lambda: _sleep_model("slow", 0.02)}
    with Executor(factories, n_workers=n_workers,
                  clock=lambda: time.monotonic() - base,
                  tracer=tracer) as ex:
        reqs = []
        for i in range(n_tasks):
            name = "fast" if i % 2 == 0 else "slow"
            lo, hi = (0.01, 0.04) if name == "fast" else (0.04, 0.09)
            dur = float(rng.uniform(lo, hi))
            reqs.append(EvalRequest(name, [[dur]], time_request=dur))
        ex.run_all(reqs, timeout=120.0)
    tracer.close_stream()
    return read_jsonl(path)


# ---------------------------------------------------------------------------
# attribution error: sim replay vs the live trace
# ---------------------------------------------------------------------------
def replay_error(spec, replay: TraceReplay, live_totals: dict,
                 n_tasks: int, *, n_workers: int, seed: int) -> dict:
    """Replay the recorded workload through `simulate_cluster` under
    `spec` and score its phase-wise attribution against the live run."""
    tracer = Tracer()
    simulate_cluster(spec, replay.trace(), n_workers=n_workers,
                     seed=seed, tracer=tracer)
    totals = attribute_overhead(tracer.events())["totals"]
    err = sum(abs(totals[k] - live_totals[k]) for k in PHASE_KEYS)
    return {"spec": spec.name,
            "attribution": {k: totals[k] for k in PHASE_KEYS},
            "abs_error_s": err,
            "error_per_task_s": err / max(n_tasks, 1)}


def drift_alarms(spec, events) -> int:
    mon = CalibrationMonitor(spec, min_n=6)
    mon.consume(events)
    return len(mon.alarms)


# ---------------------------------------------------------------------------
# round-trip identity: the replay contract on a sim-recorded trace
# ---------------------------------------------------------------------------
def roundtrip_identity() -> dict:
    """Record a kill-heavy elastic sim run, replay it, and demand bitwise
    equality of records, allocations, and makespan."""
    spec = backends.get("hq")
    cfg = AutoAllocConfig(workers_per_alloc=2, backlog_high_s=30,
                          backlog_low_s=5, max_pending=2,
                          max_allocations=4, min_allocations=0,
                          idle_drain_s=20, hysteresis_s=5, walltime_s=25)
    tracer = Tracer()
    orig = simulate_cluster(spec, bursty_trace(2, 10, seed=3),
                            autoalloc=cfg, seed=3, max_attempts=2,
                            tracer=tracer)
    replay = TraceReplay(tracer.events())
    again = simulate_cluster(replay.spec(spec), replay.trace(),
                             autoalloc=cfg, seed=999, max_attempts=2)
    return {
        "records_exact": orig.records == again.records,
        "allocations_exact": orig.allocations == again.allocations,
        "makespan_exact": (orig.summary()["makespan"]
                           == again.summary()["makespan"]),
        "n_tasks": len(orig.records),
        "n_killed_terminal": sum(r.status == "failed"
                                 for r in orig.records),
        "makespan_s": orig.summary()["makespan"],
    }


# ---------------------------------------------------------------------------
def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="calibrate the committed sample trace instead "
                         "of recording a live run (CI smoke)")
    ap.add_argument("--trace", default=None,
                    help="calibrate an existing JSONL trace")
    ap.add_argument("--trace-out", default=None,
                    help="keep the recorded live trace at this path")
    ap.add_argument("--out", default="BENCH_calibration.json")
    ap.add_argument("--n-tasks", type=int, default=24)
    ap.add_argument("--n-workers", type=int, default=3)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    if args.trace:
        trace_path = args.trace
        events = read_jsonl(trace_path)
    elif args.quick:
        trace_path = SAMPLE_TRACE
        events = read_jsonl(trace_path)
    else:
        trace_path = args.trace_out or os.path.join(
            tempfile.gettempdir(), "calibration_live_trace.jsonl")
        print(f"recording live trace -> {trace_path}")
        events = record_live_trace(trace_path, n_tasks=args.n_tasks,
                                   n_workers=args.n_workers,
                                   seed=args.seed)

    live = attribute_overhead(events)
    live_totals = {k: live["totals"][k] for k in PHASE_KEYS}
    n_tasks = live["n_tasks"]
    print(f"live trace: {len(events)} events, {n_tasks} tasks")
    print("  live attribution:",
          {k: round(v, 4) for k, v in live_totals.items()})

    base = backends.get("hq")
    cal = calibrate(events, base, label=trace_path)
    print(cal.describe_fits())

    replay = TraceReplay(events)
    rows = [replay_error(s, replay, live_totals, n_tasks,
                         n_workers=args.n_workers, seed=args.seed)
            for s in (base, cal)]
    for row in rows:
        print(f"  {row['spec']:>10s}: phase attribution error "
              f"{row['error_per_task_s']:.4f} s/task "
              f"(total {row['abs_error_s']:.3f} s)")

    base_err, cal_err = rows[0]["abs_error_s"], rows[1]["abs_error_s"]
    improvement = (1.0 - cal_err / base_err) if base_err > 0 else 0.0
    print(f"  calibration removes {improvement:.1%} of the "
          f"attribution error")

    drift = {"uncalibrated_alarms": drift_alarms(base, events),
             "calibrated_alarms": drift_alarms(cal, events)}
    print(f"  drift alarms: uncalibrated={drift['uncalibrated_alarms']} "
          f"calibrated={drift['calibrated_alarms']}")

    rt = roundtrip_identity()
    print(f"  round-trip: records_exact={rt['records_exact']} "
          f"makespan_exact={rt['makespan_exact']} "
          f"({rt['n_tasks']} tasks, {rt['n_killed_terminal']} terminal "
          f"kills, makespan {rt['makespan_s']:.1f}s)")

    problems = []
    if not (math.isfinite(cal_err) and cal_err < base_err):
        problems.append(
            f"calibrated error {cal_err:.3f}s is not strictly below "
            f"uncalibrated {base_err:.3f}s")
    if not (rt["records_exact"] and rt["allocations_exact"]
            and rt["makespan_exact"]):
        problems.append("sim trace round-trip is not exact")
    if drift["uncalibrated_alarms"] == 0:
        problems.append("uncalibrated spec raised no drift alarms on a "
                        "live trace it plainly mispredicts")
    if drift["calibrated_alarms"] > 0:
        problems.append(f"calibrated spec raised "
                        f"{drift['calibrated_alarms']} drift alarms on "
                        f"its own calibration trace")

    out = {
        "trace": trace_path,
        "n_events": len(events),
        "n_tasks": n_tasks,
        "live_attribution": live_totals,
        "specs": rows,
        "improvement": improvement,
        "drift": drift,
        "roundtrip": rt,
        "fits": cal.describe_fits(),
        "problems": problems,
    }
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    print(f"wrote {args.out}")
    if problems:
        print("PROBLEMS:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print("all calibration gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
