"""Benchmark: the multi-tenant broker service's three headline numbers.

The broker-service milestone turns the single-owner `Executor` into an
always-on, fair-share, crash-safe service (`repro.service`).  This
benchmark gates its three contracts:

  * fair-share error — tenants weighted 1:2:4 on a seeded saturating
    trace (loaded proportionally via `with_tenants`, measured at the
    3/4-drain horizon through `simulate_cluster`): max relative error of
    per-tenant CPU-second shares against the weight targets;
  * restart-recovery makespan penalty — a live `ServiceBroker` killed
    mid-workload and recovered from its journal must finish EVERY task
    (zero lost — hard-asserted), and the wall-clock penalty vs an
    uninterrupted run of the same workload is reported;
  * ingestion throughput — sustained `submit` rate through admission
    control (quota ledger + tenant-labelled counters) into the broker,
    measured with workers cold so dispatch cost stays out of the number.

Pass criteria (printed, and non-zero exit on failure):
  * zero lost tasks across the kill/recover cycle, terminal record set
    identical to the uninterrupted run's;
  * fair-share max relative error <= 10% (the milestone acceptance bar);
  * ingestion overhead stays under ``--submit-budget-us`` per task
    (default 2000 us — admission must be queue-push cheap, not
    dispatch-priced).

Writes every number to ``BENCH_broker_service.json`` (``--json`` to
move it) so future PRs can diff the trajectory.  ``--quick`` shrinks
the workloads for the CI smoke lane.

    PYTHONPATH=src python benchmarks/broker_service.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.cluster import bursty_trace, simulate_cluster, with_tenants
from repro.core import EvalRequest, backends
from repro.core.task import LambdaModel
from repro.sched import FairSharePolicy
from repro.service import ServiceBroker

WEIGHTS = {"a": 1.0, "b": 2.0, "c": 4.0}


def _req(i: int, tenant: str, task_id: str = "", sleep_s: float = 0.0
         ) -> EvalRequest:
    return EvalRequest("toy", [[float(i)]], time_request=1.0,
                       time_limit=60.0, tenant=tenant, task_id=task_id)


def _model_factory(sleep_s: float):
    def mk():
        def fn(p, c):
            if sleep_s:
                time.sleep(sleep_s)
            return [[float(p[0][0])]]
        return LambdaModel("toy", fn, 1, 1)
    return mk


# --------------------------------------------------------------------------
# 1. fair-share error (sim-measured, deterministic)
# --------------------------------------------------------------------------
def bench_fair_share(quick: bool) -> dict:
    burst = 56 if quick else 112
    trace = with_tenants(
        bursty_trace(n_bursts=1, burst_size=burst, burst_span_s=1.0,
                     runtime_s=4.0, jitter=0.0, seed=3), WEIGHTS)
    tenant_of = {f"trace-{i}": tt.tenant for i, tt in enumerate(trace)}
    res = simulate_cluster(
        backends.get("hq"), trace,
        policy=lambda: FairSharePolicy(weights=WEIGHTS, quantum_s=8.0),
        n_workers=2, seed=3)
    done = sorted((r for r in res.records if r.status == "ok"),
                  key=lambda r: r.end_t)
    part = done[:(3 * len(done)) // 4]
    cpu = {t: 0.0 for t in WEIGHTS}
    for r in part:
        cpu[tenant_of[r.task_id]] += r.cpu_time
    total = sum(cpu.values())
    wsum = sum(WEIGHTS.values())
    shares = {t: cpu[t] / total for t in WEIGHTS}
    err = {t: abs(shares[t] - w / wsum) / (w / wsum)
           for t, w in WEIGHTS.items()}
    out = {"n_tasks": len(trace), "horizon_tasks": len(part),
           "shares": shares,
           "targets": {t: w / wsum for t, w in WEIGHTS.items()},
           "max_rel_error": max(err.values())}
    print(f"fair share (1:2:4, {len(part)} tasks at 3/4 drain):")
    for t in sorted(WEIGHTS):
        print(f"  tenant {t}: share {shares[t]:.3f} "
              f"(target {WEIGHTS[t] / wsum:.3f}, err {err[t]:.1%})")
    print(f"  max relative error: {out['max_rel_error']:.1%}")
    return out


# --------------------------------------------------------------------------
# 2. restart-recovery makespan penalty (live, kill mid-workload)
# --------------------------------------------------------------------------
def bench_recovery(quick: bool, tmpdir: str) -> dict:
    n = 16 if quick else 40
    sleep_s = 0.02 if quick else 0.05
    reqs = [_req(i, "a" if i % 3 else "b", task_id=f"bench-{i}")
            for i in range(n)]

    def run_uninterrupted() -> tuple:
        t0 = time.monotonic()
        with ServiceBroker({"toy": _model_factory(sleep_s)},
                           n_workers=2) as svc:
            ids = [svc.submit(EvalRequest(
                "toy", r.parameters, time_request=1.0, time_limit=60.0,
                tenant=r.tenant, task_id=r.task_id)) for r in reqs]
            res = [svc.result(t, timeout=120.0) for t in ids]
        return time.monotonic() - t0, {(r.task_id, r.status) for r in res}

    base_s, base_terminal = run_uninterrupted()

    t0 = time.monotonic()
    svc = ServiceBroker({"toy": _model_factory(sleep_s)},
                        weights=WEIGHTS,
                        journal_dir=tmpdir, journal_every_s=0.02,
                        n_workers=2)
    ids = [svc.submit(r) for r in reqs]
    while len([r for r in svc.records() if r.status == "ok"]) < n // 3:
        time.sleep(0.005)
    svc.checkpoint()
    svc.kill()
    done_before = len([r for r in svc.records() if r.status == "ok"])

    svc2 = ServiceBroker.recover({"toy": _model_factory(sleep_s)},
                                 journal_dir=tmpdir, n_workers=2)
    res = [svc2.result(t, timeout=120.0) for t in ids]
    svc2.shutdown()
    recovered_s = time.monotonic() - t0
    terminal = {(r.task_id, r.status) for r in res}

    lost = len(reqs) - len(terminal)
    assert lost == 0, f"{lost} tasks lost across the kill/recover cycle"
    assert terminal == base_terminal, \
        "recovered terminal record set differs from the uninterrupted run"
    out = {"n_tasks": n, "done_before_kill": done_before,
           "lost_tasks": lost,
           "uninterrupted_s": base_s, "kill_recover_s": recovered_s,
           "makespan_penalty": recovered_s / base_s - 1.0}
    print(f"restart recovery ({n} tasks, killed after {done_before}):")
    print(f"  uninterrupted: {base_s:.2f}s   kill+recover: "
          f"{recovered_s:.2f}s   penalty: {out['makespan_penalty']:+.1%}")
    print(f"  lost tasks: {lost} (zero required)")
    return out


# --------------------------------------------------------------------------
# 3. ingestion throughput (admission control hot path)
# --------------------------------------------------------------------------
def bench_ingestion(quick: bool) -> dict:
    n = 2_000 if quick else 20_000
    # zero workers: measure admission (quota ledger + labelled counters +
    # broker push), not model dispatch
    svc = ServiceBroker({"toy": _model_factory(0.0)}, n_workers=0,
                        weights=WEIGHTS,
                        quotas={t: n * 2 for t in WEIGHTS})
    tenants = sorted(WEIGHTS)
    reqs = [_req(i, tenants[i % 3]) for i in range(n)]
    t0 = time.monotonic()
    for r in reqs:
        svc.submit(r)
    dt = time.monotonic() - t0
    svc.kill()                     # n_workers=0: nothing in flight
    out = {"n_tasks": n, "total_s": dt,
           "per_submit_us": dt / n * 1e6,
           "submits_per_s": n / dt}
    print(f"ingestion: {n} submits in {dt:.3f}s  "
          f"({out['per_submit_us']:.1f} us/task, "
          f"{out['submits_per_s']:.0f}/s)")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workloads (the CI smoke lane)")
    ap.add_argument("--json", default="BENCH_broker_service.json")
    ap.add_argument("--submit-budget-us", type=float, default=2000.0)
    args = ap.parse_args()

    import tempfile
    results = {"quick": args.quick}
    results["fair_share"] = bench_fair_share(args.quick)
    with tempfile.TemporaryDirectory() as d:
        results["recovery"] = bench_recovery(args.quick, d)
    results["ingestion"] = bench_ingestion(args.quick)

    failures = []
    if results["recovery"]["lost_tasks"] != 0:
        failures.append("tasks lost across kill/recover")
    if results["fair_share"]["max_rel_error"] > 0.10:
        failures.append(
            f"fair-share error {results['fair_share']['max_rel_error']:.1%}"
            " > 10%")
    if results["ingestion"]["per_submit_us"] > args.submit_budget_us:
        failures.append(
            f"ingestion {results['ingestion']['per_submit_us']:.0f} us/task"
            f" > budget {args.submit_budget_us:.0f} us")
    results["pass"] = not failures

    with open(args.json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"\nwrote {args.json}")
    if failures:
        print("FAIL: " + "; ".join(failures))
        return 1
    print("PASS: zero lost tasks, fair-share error <= 10%, "
          "ingestion within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
