"""Benchmark: roofline table aggregation from the dry-run artifacts.

Reads experiments/dryrun/*.json (written by `python -m repro.launch.dryrun
--all --mesh both`) and emits the per-(arch x shape x mesh) roofline rows:
three terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load() -> List[Dict]:
    out = []
    for p in sorted(RESULTS_DIR.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def run() -> List[Dict]:
    rows = []
    for rec in load():
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": "skipped"})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh"), "status": "FAILED"})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "tag": rec.get("tag", ""),
            "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_flops_ratio": rec.get("useful_flops_ratio", 0.0),
            "fits_hbm_16g": rec.get("fits_hbm_16g"),
            "roofline_fraction": (
                max(r["compute_s"], 1e-12)
                / max(r["compute_s"], r["memory_s"], r["collective_s"])),
        })
    return rows


def summary(rows: List[Dict]) -> Dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    dom = {}
    for r in ok:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return {"cells_ok": len(ok),
            "cells_skipped": sum(r.get("status") == "skipped" for r in rows),
            "cells_failed": sum(r.get("status") == "FAILED" for r in rows),
            "dominant_histogram": dom}
