"""Benchmark: scheduling hot-path overhead as queues grow to 1M tasks.

The paper's premise is queues of "thousands or even millions of similar
tasks", and its headline result is that scheduling *overhead* — not the
physics — dominates at scale.  This benchmark is the repo's perf anchor
for that claim: per-task push/pop overhead for every registered
single-node policy at 1k/10k/100k/1M queued tasks, the latency of a full
GP-costed re-scoring (one batched `predict_many` pass), and end-to-end
`simulate_cluster` throughput.  A healthy run shows FLAT per-op
overhead across three orders of magnitude of queue size — the O(log n)
guarantee of `repro.sched.costq` — while the pre-PR pack implementation
(kept here as `NaivePack`, the literal old code) degrades linearly and
worse.

Pass criteria (printed, and non-zero exit on failure):
  * pack pop throughput at the largest compared size is >= 10x the
    naive implementation's;
  * a full GP-costed rebuild issues at most len(gp.PREDICT_BUCKETS)
    distinct compile shapes (asserted via `gp.predict_batch_shapes`);
  * with ``--quick`` (the CI gate): pack per-pop overhead at 10k queued
    stays under ``--pop-budget-us`` (default 1000 us — an order of
    magnitude below what the old sort-per-pop cost at that size).

Writes every number to ``BENCH_queue_scale.json`` (``--json`` to move
it) so future PRs can diff the trajectory.

    PYTHONPATH=src python benchmarks/queue_scale.py [--quick]
"""
from __future__ import annotations

import argparse
import heapq
import json
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.traces import bimodal_trace
from repro.core import backends
from repro.cluster import simulate_cluster
from repro.sched import GPRuntimePredictor, WorkerView, make_policy
from repro.sched.policy import SchedulingPolicy
from repro.uq import gp

POLICIES = ("fcfs", "sjf", "lpt", "pack", "steal", "edf")
SIZES = (1_000, 10_000, 100_000, 1_000_000)
QUICK_SIZES = (1_000, 10_000)
NAIVE_MAX = 100_000        # naive pack is too slow beyond this (by design)
NAIVE_POPS = 30            # pops to sample when a full drain is hopeless
MODELS = ("gs2", "proxy", "cheap")


class NaivePack(SchedulingPolicy):
    """The pre-PR `PackingPolicy`: heap + sort-scan-remove-heapify on
    every budget-fit pop — O(n log n) per decision.  Kept verbatim as
    the baseline the 10x criterion is measured against."""

    name = "naive-pack"
    sign = -1.0

    def __init__(self, predictor=None, init_margin: float = 1.0):
        super().__init__(predictor)
        self.init_margin = init_margin
        self._heap = []

    def push(self, req, attempt):
        heapq.heappush(self._heap, (self.sign * self.cost(req),
                                    next(self._tick), (req, attempt)))

    def pop(self, worker=None):
        if not self._heap:
            return None
        if worker is None or worker.budget_left is None:
            return heapq.heappop(self._heap)[2]
        budget = worker.budget_left - self.init_margin
        order = sorted(self._heap)
        for entry in order:
            if -entry[0] <= budget:
                self._heap.remove(entry)
                heapq.heapify(self._heap)
                return entry[2]
        entry = order[-1]
        self._heap.remove(entry)
        heapq.heapify(self._heap)
        return entry[2]

    def pending(self):
        return [item for _, _, item in sorted(self._heap)]

    def __len__(self):
        return len(self._heap)


def make_requests(n: int, seed: int = 0, gp_params: bool = False):
    from repro.core.task import EvalRequest
    rng = np.random.default_rng(seed)
    costs = rng.lognormal(mean=2.0, sigma=1.0, size=n)
    xs = rng.uniform(0.0, 1.0, size=(n, 2)) if gp_params else None
    reqs = []
    for i in range(n):
        reqs.append(EvalRequest(
            model_name=MODELS[i % len(MODELS)],
            parameters=([[float(xs[i, 0]), float(xs[i, 1])]] if gp_params
                        else [[float(i)]]),
            time_request=float(costs[i]),
            deadline=float(rng.uniform(0, 1e4)),
            task_id=f"qs-{i}"))
    return reqs


def make_views(n: int, seed: int = 1) -> List[Optional[WorkerView]]:
    """A rotating pool of pop-side worker views: finite budgets (the
    pack budget-fit path), warm models (the steal index path), several
    wids (per-worker queues)."""
    rng = np.random.default_rng(seed)
    views: List[Optional[WorkerView]] = []
    for i in range(n):
        views.append(WorkerView(
            wid=i % 8,
            warm_models=frozenset({MODELS[i % len(MODELS)]}),
            budget_left=(float(rng.uniform(1.0, 120.0))
                         if i % 4 else None)))
    return views


def bench_policy(name: str, reqs, views, *, max_pops: Optional[int] = None,
                 factory=None) -> Dict[str, float]:
    """Push all of `reqs`, then pop (fully, or `max_pops` samples);
    returns per-op throughput."""
    pol = factory() if factory is not None else make_policy(name)
    t0 = time.perf_counter()
    for req in reqs:
        pol.push(req, 1)
    t_push = time.perf_counter() - t0
    n_pops = len(reqs) if max_pops is None else min(max_pops, len(reqs))
    t0 = time.perf_counter()
    got = 0
    for i in range(n_pops):
        if pol.pop(views[i % len(views)]) is not None:
            got += 1
    t_pop = time.perf_counter() - t0
    assert got == n_pops, f"{name}: queue lost items ({got}/{n_pops})"
    return {
        "policy": name, "n": len(reqs), "n_pops": n_pops,
        "push_per_s": len(reqs) / max(t_push, 1e-9),
        "pop_per_s": n_pops / max(t_pop, 1e-9),
        "pop_us": 1e6 * t_pop / max(n_pops, 1),
    }


def bench_rebuild(n: int, seed: int = 5) -> Dict[str, float]:
    """Latency of a full GP-costed re-scoring of an n-task queue — one
    batched `predict_many` pass through `gp.predict_batch` — plus the
    compile-shape bill it ran at."""
    rng = np.random.default_rng(seed)
    pred = GPRuntimePredictor(min_fit=8, refit_every=10_000, fit_steps=30)
    from repro.core.task import EvalRequest

    def observe(k: int):
        for x in rng.uniform(0, 1, size=(k, 2)):
            pred.observe(EvalRequest("gs2", [list(map(float, x))]),
                         float(1.0 + 3.0 * x[0] + x[1]))

    observe(16)                                # fit + one conditioning
    pol = make_policy("sjf", pred)
    for req in make_requests(n, seed=seed, gp_params=True):
        pol.push(req, 1)
    timings = []
    shapes_new: Dict = {}
    for round_i in range(2):                   # cold (compiles), then warm
        if round_i == 0:
            observe(8)                         # posterior install: version
        else:
            pol._built_version = None          # same posterior: no fresh
        before = dict(gp.predict_batch_shapes)  # XLA shapes, pure rebuild
        t0 = time.perf_counter()
        assert pol.pop() is not None           # triggers the rebuild
        timings.append(time.perf_counter() - t0)
        shapes_new = {k: v - before.get(k, 0)
                      for k, v in gp.predict_batch_shapes.items()
                      if v - before.get(k, 0) > 0}
        n_shapes = len(shapes_new)
        assert n_shapes <= len(gp.PREDICT_BUCKETS), (
            f"GP rebuild at n={n} issued {n_shapes} compile shapes "
            f"({shapes_new}) — bucket discipline broken")
    return {
        "n": n,
        "rebuild_cold_s": timings[0],
        "rebuild_warm_s": timings[1],
        "rebuild_warm_us_per_task": 1e6 * timings[1] / n,
        "compile_shapes": len(shapes_new),
        "launches": sum(shapes_new.values()),
    }


def bench_tracing(n: int, seed: int = 7) -> Dict[str, float]:
    """Per-op cost of a full Broker push+pop cycle with and without a
    `repro.obs.Tracer` attached — the opt-in tracing layer must stay
    within 5% of the per-op budget (`--quick` gate: traced per-op
    <= 1.05x ``--pop-budget-us``; budget-relative, so wall-clock noise
    between the two runs cannot flake the gate)."""
    from repro.cluster import Allocation, Broker
    from repro.obs import Tracer

    out: Dict[str, float] = {"n": n}
    for label, tracer in (("untraced_us", None),
                          ("traced_us", Tracer(capacity=4 * n))):
        broker = Broker()
        alloc = Allocation(broker.next_alloc_id(), 8, None)
        alloc.submit(0.0, 0.0)
        alloc.tick(0.0)                        # zero queue wait: RUNNING
        broker.add_allocation(alloc)
        if tracer is not None:
            broker.set_tracer(tracer)
        view = WorkerView(wid=0, warm_models=frozenset(),
                          budget_left=None, alloc_id=alloc.alloc_id)
        reqs = make_requests(n, seed=seed)
        t0 = time.perf_counter()
        for req in reqs:
            broker.push(req, 1)
        got = 0
        while broker.pop(view) is not None:
            got += 1
        wall = time.perf_counter() - t0
        assert got == n, f"broker lost items ({got}/{n})"
        out[label] = 1e6 * wall / n
    out["overhead_frac"] = out["traced_us"] / out["untraced_us"] - 1.0
    return out


def bench_sim(n_tasks: int, seed: int = 3) -> Dict[str, float]:
    """End-to-end `simulate_cluster` throughput (tasks scheduled per
    wall-second of simulator time) under the pack policy."""
    spec = backends.get("hq")
    trace = bimodal_trace(n=n_tasks, seed=seed)
    t0 = time.perf_counter()
    res = simulate_cluster(spec, trace, policy="pack", n_workers=8,
                           seed=seed)
    wall = time.perf_counter() - t0
    assert len(res.records) == n_tasks
    return {"n_tasks": n_tasks, "wall_s": wall,
            "tasks_per_s": n_tasks / max(wall, 1e-9)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI gate: small sizes + hard per-pop budget")
    ap.add_argument("--json", default="BENCH_queue_scale.json")
    ap.add_argument("--pop-budget-us", type=float, default=1000.0,
                    help="--quick fails if pack per-pop at 10k exceeds this")
    args = ap.parse_args(argv)

    sizes = QUICK_SIZES if args.quick else SIZES
    views = make_views(4096)
    rows: List[Dict] = []
    naive_rows: List[Dict] = []
    print(f"queue-scale: sizes={list(sizes)} policies={list(POLICIES)}")
    for n in sizes:
        reqs = make_requests(n)
        for name in POLICIES:
            row = bench_policy(name, reqs, views)
            rows.append(row)
            print(f"  n={n:>9,} {name:>6}: "
                  f"push {row['push_per_s']:>12,.0f}/s   "
                  f"pop {row['pop_per_s']:>12,.0f}/s "
                  f"({row['pop_us']:.1f} us/pop)")
        if n <= NAIVE_MAX:
            naive = bench_policy(
                "naive-pack", reqs, views,
                max_pops=(None if n <= 1_000 else NAIVE_POPS),
                factory=NaivePack)
            naive_rows.append(naive)
            print(f"  n={n:>9,} naive-pack: "
                  f"pop {naive['pop_per_s']:>12,.0f}/s "
                  f"({naive['pop_us']:.1f} us/pop, "
                  f"{naive['n_pops']} sampled)")

    rebuild_sizes = [s for s in sizes if s <= 100_000] if not args.quick \
        else [1_000]
    rebuilds = []
    for n in rebuild_sizes:
        r = bench_rebuild(n)
        rebuilds.append(r)
        print(f"  GP rebuild n={n:>7,}: warm {r['rebuild_warm_s']*1e3:.1f} ms"
              f" ({r['rebuild_warm_us_per_task']:.2f} us/task, "
              f"{r['compile_shapes']} compile shapes, "
              f"{r['launches']} launches)")

    sim = bench_sim(300 if args.quick else 3_000)
    print(f"  simulate_cluster: {sim['n_tasks']} tasks in "
          f"{sim['wall_s']:.2f} s -> {sim['tasks_per_s']:,.0f} tasks/s")

    tracing = bench_tracing(10_000)
    print(f"  tracing overhead (broker push+pop, n=10,000): "
          f"untraced {tracing['untraced_us']:.2f} us/op, "
          f"traced {tracing['traced_us']:.2f} us/op "
          f"({tracing['overhead_frac']:+.1%})")

    # ---- criteria ------------------------------------------------------
    by = {(r["policy"], r["n"]): r for r in rows}
    naive_by = {r["n"]: r for r in naive_rows}
    cmp_n = max(naive_by)                      # largest compared size
    speedup = (by[("pack", cmp_n)]["pop_per_s"]
               / naive_by[cmp_n]["pop_per_s"])
    ok = speedup >= 10.0
    print(f"\npack pop speedup vs naive at n={cmp_n:,}: {speedup:,.1f}x "
          f"(criterion >= 10x) -> {'PASS' if ok else 'FAIL'}")
    budget_ok = True
    traced_ok = True
    if args.quick:
        pack_10k = by[("pack", 10_000)]["pop_us"]
        budget_ok = pack_10k <= args.pop_budget_us
        print(f"pack per-pop at 10k queued: {pack_10k:.1f} us "
              f"(budget {args.pop_budget_us:.0f} us) -> "
              f"{'PASS' if budget_ok else 'FAIL'}")
        traced_budget = 1.05 * args.pop_budget_us
        traced_ok = tracing["traced_us"] <= traced_budget
        print(f"traced broker per-op at 10k: {tracing['traced_us']:.1f} us"
              f" (budget {traced_budget:.0f} us = 1.05x pop budget) -> "
              f"{'PASS' if traced_ok else 'FAIL'}")

    out = {
        "bench": "queue_scale",
        "quick": bool(args.quick),
        "policies": rows,
        "naive_pack": naive_rows,
        "rebuild": rebuilds,
        "simulate_cluster": sim,
        "tracing": tracing,
        "criteria": {
            "pack_vs_naive_speedup": speedup,
            "pack_vs_naive_at_n": cmp_n,
            "speedup_ok": bool(ok),
            "pop_budget_us": args.pop_budget_us,
            "pop_budget_ok": bool(budget_ok),
            "traced_budget_us": 1.05 * args.pop_budget_us,
            "traced_budget_ok": bool(traced_ok),
        },
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")
    return 0 if (ok and budget_ok and traced_ok) else 1


if __name__ == "__main__":
    sys.exit(main())
