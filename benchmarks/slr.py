"""Benchmark: Schedule Length Ratio comparison (paper Fig. 4).

SLR = makespan / sum_i C_i for each (application x scheduler x queue
depth); HQ should sit near the work-conserving bound, SLURM far above it
for short tasks.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import workloads
from repro.core import backends, eval_records, metrics, simulate

SEEDS = (3, 7, 13, 29, 41)


def run(n_evals: int = workloads.N_EVALS) -> List[Dict]:
    rows = []
    for bench in workloads.BENCHMARKS:
        w = workloads.make_workload(bench, n_evals=n_evals)
        for q in workloads.QUEUE_DEPTHS:
            for backend in ("slurm", "hq"):
                vals = []
                for seed in SEEDS:
                    recs = eval_records(
                        simulate(backends.get(backend), w, q, seed=seed))
                    vals.append(metrics.slr(recs))
                v = np.array(vals)
                rows.append({"bench": bench, "scheduler": backend,
                             "queue": q,
                             "slr_median": float(np.median(v)),
                             "slr_min": float(v.min()),
                             "slr_max": float(v.max())})
    return rows
