"""Benchmark-job guard: sim/live lifecycle parity on seeded traces.

The elasticity and offload benchmarks are only meaningful if the
simulator that produces their numbers is the same machine as the live
executor.  This check drives several seeded scenarios through BOTH
adapters of the shared `LifecycleStepper` — `simulate_cluster` and
`replay_live` (the real `Executor` on a virtual clock) — and fails the
build on ANY divergence in allocation decisions, spawn/retire event
sequences, terminal task records, or allocation billing.

    PYTHONPATH=src python benchmarks/parity.py [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Tuple

from repro.cluster import (AutoAllocConfig, bimodal_trace, bursty_trace,
                           run_parity)
from repro.core import backends


def _elastic_cfg(**kw) -> AutoAllocConfig:
    base = dict(workers_per_alloc=2, walltime_s=300.0, backlog_high_s=30.0,
                backlog_low_s=5.0, max_pending=2, max_allocations=4,
                min_allocations=0, idle_drain_s=20.0, hysteresis_s=5.0)
    base.update(kw)
    return AutoAllocConfig(**base)


def scenarios(quick: bool) -> List[Tuple[str, Dict]]:
    n = 20 if quick else 60
    bursts = 2 if quick else 4
    out: List[Tuple[str, Dict]] = [
        ("static-pool", dict(
            trace=bimodal_trace(n=n, seed=4), n_workers=3, seed=9)),
        ("elastic-autoalloc", dict(
            trace=bursty_trace(n_bursts=bursts, burst_size=8, gap_s=300.0,
                               runtime_s=10.0, seed=1),
            autoalloc=_elastic_cfg(), max_workers=16, seed=1)),
        ("walltime-kill", dict(
            trace=bursty_trace(n_bursts=1, burst_size=4, burst_span_s=1.0,
                               runtime_s=40.0, jitter=0.0, seed=0),
            autoalloc=_elastic_cfg(workers_per_alloc=1, walltime_s=60.0,
                                   idle_drain_s=50.0),
            max_attempts=6, seed=3)),
        ("capped-grants", dict(
            trace=bursty_trace(n_bursts=1, burst_size=16, burst_span_s=2.0,
                               runtime_s=30.0, seed=5),
            autoalloc=_elastic_cfg(workers_per_alloc=8, backlog_high_s=5.0,
                                   max_allocations=8, max_pending=4),
            max_workers=5, seed=5)),
    ]
    if not quick:
        out.append(("terminal-failures", dict(
            trace=bursty_trace(n_bursts=1, burst_size=6, burst_span_s=1.0,
                               runtime_s=50.0, jitter=0.0, seed=0),
            n_workers=1, walltime_s=60.0, max_attempts=1, seed=0)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller traces (CI smoke size)")
    args = ap.parse_args(argv)

    spec = backends.get("hq")
    failures = 0
    for name, kw in scenarios(args.quick):
        t0 = time.perf_counter()
        rep = run_parity(spec, **kw)
        dt = time.perf_counter() - t0
        n_tasks = len(rep.sim.records)
        n_dec = len(rep.sim.decisions)
        status = "ok" if rep.ok else f"{len(rep.divergences)} DIVERGENCES"
        print(f"{name:<20} tasks={n_tasks:<4} decisions={n_dec:<4} "
              f"events={len(rep.sim.events):<4} [{dt * 1e3:6.1f} ms] "
              f"{status}")
        if not rep.ok:
            failures += 1
            for d in rep.divergences[:10]:
                print(f"    {d}")
    verdict = "PASS" if failures == 0 else "FAIL"
    print(f"\n{verdict}: sim and live lifecycle "
          f"{'agree on every scenario' if failures == 0 else 'DIVERGED'} "
          f"(one stepper, two adapters)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
