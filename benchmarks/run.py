"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Emits CSV blocks per benchmark plus the derived headline numbers that
EXPERIMENTS.md §Paper-validation quotes.
"""
from __future__ import annotations

import argparse
import sys


def _csv(rows, keys=None):
    if not rows:
        print("(no rows)")
        return
    keys = keys or list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        vals = []
        for k in keys:
            v = r.get(k, "")
            vals.append(f"{v:.6g}" if isinstance(v, float) else str(v))
        print(",".join(vals))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer evaluations / smaller sizes")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    n_evals = 30 if args.quick else 100
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    if want("table3"):
        from repro.configs.workloads import resource_table
        print("\n== Table III: resource requests ==")
        rows = [{"bench": k, **v} for k, v in resource_table().items()]
        _csv(rows)

    if want("scheduler"):
        from benchmarks import scheduler_comparison
        print("\n== Fig. 3: scheduler comparison (makespan / CPU / overhead) ==")
        rows = scheduler_comparison.run(n_evals=n_evals)
        _csv(rows)
        print("\n-- derived headline numbers --")
        for k, v in scheduler_comparison.derived(rows).items():
            print(f"{k},{v:.4g}")

    if want("slr"):
        from benchmarks import slr
        print("\n== Fig. 4: SLR ==")
        _csv(slr.run(n_evals=n_evals))

    if want("umb"):
        from benchmarks import umb_slurm
        print("\n== Figs. 5-6 (Appendix A): UM-Bridge SLURM backend ==")
        _csv(umb_slurm.run(n_evals=n_evals))

    if want("gp"):
        from benchmarks import gp_throughput
        print("\n== GP surrogate throughput ==")
        _csv(gp_throughput.run(sizes=(128, 512) if args.quick
                               else (128, 512, 1024)))

    if want("live"):
        from benchmarks import executor_live
        print("\n== Live executor: real JAX tasks (GS2 proxy + GP) ==")
        _csv(executor_live.run(n_tasks=12 if args.quick else 24))

    if want("roofline"):
        from benchmarks import roofline
        print("\n== Roofline table (from dry-run artifacts) ==")
        rows = roofline.run()
        if rows:
            _csv(rows)
            print("\n-- summary --")
            for k, v in roofline.summary(rows).items():
                print(f"{k},{v}")
        else:
            print("(run `python -m repro.launch.dryrun --all --mesh both` first)")


if __name__ == "__main__":
    main()
