"""Benchmark: scheduler comparison (paper Fig. 3).

Reproduces the six-panel experiment: {makespan, CPU time, scheduling
overhead} x {2, 10 jobs in queue} for the four applications under naive
SLURM and HQ.  Emits one CSV row per (app, scheduler, queue-depth) with
boxplot statistics over several seeds.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import workloads
from repro.core import backends, eval_records, metrics, simulate

SEEDS = (3, 7, 13, 29, 41)


def run(n_evals: int = workloads.N_EVALS) -> List[Dict]:
    rows = []
    for bench in workloads.BENCHMARKS:
        w = workloads.make_workload(bench, n_evals=n_evals)
        for q in workloads.QUEUE_DEPTHS:
            for backend in ("slurm", "hq"):
                spec = backends.get(backend)
                mk, cpu, ovh, slr_v = [], [], [], []
                for seed in SEEDS:
                    recs = eval_records(simulate(spec, w, q, seed=seed))
                    s = metrics.summarize(bench, backend, recs)
                    mk.append(s.makespan)
                    cpu.append(s.total_cpu_time)
                    ovh.append(s.overhead_stats["median"])
                    slr_v.append(s.slr)
                rows.append({
                    "bench": bench, "scheduler": backend, "queue": q,
                    "makespan_mean": float(np.mean(mk)),
                    "makespan_std": float(np.std(mk)),
                    "cpu_time_mean": float(np.mean(cpu)),
                    "overhead_median": float(np.mean(ovh)),
                    "slr_mean": float(np.mean(slr_v)),
                })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by = {(r["bench"], r["scheduler"], r["queue"]): r for r in rows}
    gs2_red = np.mean([
        1 - by[("gs2", "hq", q)]["makespan_mean"]
        / by[("gs2", "slurm", q)]["makespan_mean"]
        for q in workloads.QUEUE_DEPTHS])
    ovh_ratio = max(
        by[(b, "slurm", 2)]["overhead_median"]
        / max(by[(b, "hq", 2)]["overhead_median"], 1e-9)
        for b in workloads.BENCHMARKS)
    e100 = (by[("eigen-100", "slurm", 2)]["makespan_mean"]
            / by[("eigen-100", "hq", 2)]["makespan_mean"])
    return {"gs2_makespan_reduction": float(gs2_red),
            "max_overhead_ratio": float(ovh_ratio),
            "eigen100_speedup_q2": float(e100)}
