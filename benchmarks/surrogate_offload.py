"""Benchmark: surrogate-offload routing vs a no-offload baseline.

The paper's headline win for long-running simulations (up to 38% CPU-time
reduction) comes from substituting the GP surrogate for the expensive GS2
run wherever the surrogate is trustworthy.  This benchmark reproduces the
scenario end-to-end through the dispatch stack:

  * a seeded bimodal long-tail arrival trace (cheap majority, expensive
    minority with lognormal jitter) where every task carries a physics
    input theta; thetas fall either inside the surrogate's training
    region (trusted) or far outside it (untrusted);
  * a GP surrogate (2 outputs on deliberately different scales — the
    growth-rate/mode-frequency split that makes per-output variance
    matter) trained on a seeded design over the trusted region;
  * the SAME trace simulated twice: a no-offload baseline Broker, and a
    Broker with `SurrogateOffload` attached as a zero-queue-wait virtual
    allocation — tasks whose predicted runtime exceeds the budget AND
    whose posterior sd at theta is below the trust threshold run as a
    GP predict instead of the forward model.

Headline (printed PASS criterion): >= 20% CPU-seconds saved vs the
baseline at bounded QoI error on the offloaded tasks (normalised RMSE
<= 0.15 against the true function), with the offload decisions scored
through `gp.predict_batch` — at most 3 distinct compile shapes for the
whole queue.

CI-feasible: discrete-event simulation + small GP fits.

    PYTHONPATH=src python benchmarks/surrogate_offload.py [--quick]
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Tuple

import numpy as np

from repro.cluster import Broker, TraceTask, simulate_cluster
from repro.core import backends, metrics
from repro.sched.offload import SurrogateOffload
from repro.uq import gp as gp_lib

SEEDS = (3, 7, 13)
RUNTIME_BUDGET_S = 30.0
SD_THRESHOLD = 0.2
QOI_NRMSE_BOUND = 0.15


def truth(theta: np.ndarray) -> List[float]:
    """Synthetic 2-output QoI with a ~100x scale split between outputs
    (the growth-rate vs mode-frequency situation)."""
    return [float(np.sin(3.0 * theta[0]) + theta[1]),
            float(100.0 * np.cos(2.0 * theta[1]) + 10.0 * theta[0])]


def train_surrogate(n_train: int, seed: int) -> gp_lib.GPPosterior:
    rng = np.random.default_rng(seed)
    xs = rng.random((n_train, 2)).astype(np.float32)       # trusted region
    ys = np.array([truth(x) for x in xs], np.float32)
    return gp_lib.fit(xs, ys, steps=120)


def make_trace(n: int, seed: int) -> Tuple[List[TraceTask], Dict[str, np.ndarray]]:
    """Bimodal long-tail arrivals; ~70% of thetas inside the trusted
    region, the rest far outside.  Returns (trace, task_id -> theta)."""
    rng = np.random.default_rng(seed)
    thetas: Dict[str, np.ndarray] = {}
    out: List[TraceTask] = []
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(5.0))
        expensive = rng.uniform() < 0.4
        base = 120.0 if expensive else 4.0
        runtime = base * float(np.exp(0.3 * rng.standard_normal()))
        theta = (rng.random(2) if rng.uniform() < 0.7
                 else 2.0 + rng.random(2))
        thetas[f"trace-{i}"] = theta
        out.append(TraceTask(
            t=t, runtime=runtime, model_name="gs2",
            time_request=base,
            parameters=[[float(theta[0]), float(theta[1])]]))
    return out, thetas


def run_pair(n_tasks: int, n_train: int, seed: int) -> Dict[str, float]:
    spec = backends.get("hq")
    trace, thetas = make_trace(n_tasks, seed)
    post = train_surrogate(n_train, seed)

    base = simulate_cluster(spec, trace, n_workers=4, seed=seed)
    sur = SurrogateOffload(post, runtime_budget_s=RUNTIME_BUDGET_S,
                           sd_threshold=SD_THRESHOLD, latency_s=0.05)
    broker = Broker(policy="fcfs", surrogate=sur)
    off = simulate_cluster(spec, trace, broker=broker, n_workers=4,
                           seed=seed)
    for res, label in ((base, "baseline"), (off, "offload")):
        s = res.summary()
        assert s["n_ok"] == s["n_tasks"], (label, seed, s)

    # QoI error on the tasks that actually took the surrogate path —
    # identified by their record (surrogate runs bill exactly latency_s,
    # no server init), cross-checked against the engine's own count so a
    # broken filter can never vacuously pass the QoI bound
    offloaded = [r.task_id for r in off.records
                 if abs(r.cpu_time - sur.latency_s) < 1e-9]
    assert len(offloaded) == sur.stats().n_offloaded > 0, \
        (len(offloaded), sur.stats().n_offloaded)
    errs: List[float] = []
    y_scale = np.maximum(np.asarray(post.y_std, float), 1e-12)
    for tid in offloaded:
        theta = thetas[tid]
        mean, _ = gp_lib.predict_batch(post, theta[None].astype(np.float32))
        err = (np.asarray(mean, float)[0] - np.asarray(truth(theta))) / y_scale
        errs.append(float(np.sqrt(np.mean(err ** 2))))
    stats = sur.stats()
    return {
        "cpu_base": metrics.total_cpu_time(base.records),
        "cpu_off": metrics.total_cpu_time(off.records),
        "makespan_base": metrics.makespan(base.records),
        "makespan_off": metrics.makespan(off.records),
        "n_offloaded": float(stats.n_offloaded),
        "n_tasks": float(len(trace)),
        "qoi_nrmse": float(np.mean(errs)) if errs else 0.0,
        "cpu_seconds_avoided": stats.cpu_seconds_avoided,
    }


def batch_shape_count(n_train: int, queue: int = 512) -> int:
    """Distinct compile shapes `gp.predict_batch` uses to score a
    `queue`-task backlog fed in realistic (growing) slices."""
    post = train_surrogate(n_train, seed=0)
    rng = np.random.default_rng(0)
    gp_lib.predict_batch_shapes.clear()
    scored = 0
    for size in (1, 3, 17, 63, 120, 256, 52):   # 512 thetas total
        gp_lib.predict_batch(post, rng.random((size, 2)).astype(np.float32))
        scored += size
    assert scored == queue, scored
    return len(gp_lib.predict_batch_shapes)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny trace + one seed (CI smoke)")
    args = ap.parse_args()
    seeds = SEEDS[:1] if args.quick else SEEDS
    n_tasks = 30 if args.quick else 80
    n_train = 32 if args.quick else 64

    rows = [run_pair(n_tasks, n_train, seed) for seed in seeds]
    cols = ("cpu_base", "cpu_off", "n_offloaded", "qoi_nrmse",
            "makespan_base", "makespan_off")
    print("| seed | " + " | ".join(cols) + " |")
    print("|" + "|".join("---" for _ in range(len(cols) + 1)) + "|")
    for seed, r in zip(seeds, rows):
        print(f"| {seed} | " + " | ".join(f"{r[c]:.2f}" for c in cols) + " |")
    print()

    cpu_base = float(np.mean([r["cpu_base"] for r in rows]))
    cpu_off = float(np.mean([r["cpu_off"] for r in rows]))
    saving = 1.0 - cpu_off / cpu_base
    nrmse = float(np.max([r["qoi_nrmse"] for r in rows]))
    offl = float(np.mean([r["n_offloaded"] for r in rows]))
    shapes = batch_shape_count(n_train)

    print(f"CPU-seconds saved      : {saving:+.1%}")
    print(f"tasks offloaded (mean) : {offl:.1f} / {rows[0]['n_tasks']:.0f}")
    print(f"QoI normalised RMSE    : {nrmse:.4f} (bound {QOI_NRMSE_BOUND})")
    print(f"predict_batch shapes   : {shapes} for a 512-task queue (<= 3)")
    ok = saving >= 0.20 and nrmse <= QOI_NRMSE_BOUND and shapes <= 3
    print(f"surrogate offload claim (>=20% CPU saved at bounded QoI "
          f"error, <=3 compile shapes): {'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
