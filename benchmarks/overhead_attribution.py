"""Benchmark: where does scheduling overhead actually go?

The paper (§IV-A) reports overhead as one scalar per task:
``(end - submit) - cpu_time``.  This benchmark runs traced
`simulate_cluster` scenarios through `repro.obs` and decomposes that
scalar into its additive components — queue wait (capacity existed but
was busy), allocation wait (no open allocation: autoalloc bootstrap /
SLURM-queue share), dispatch latency, and retry (work burned by
walltime kills) — and prints the attribution table per scenario:

  * ``static``   — fixed pool, bursty arrivals: queue wait plus the
    initial allocation's own modelled SLURM-queue wait (alloc wait);
  * ``elastic``  — autoalloc with a short walltime: alloc-wait and
    retry components appear (the elasticity trade the paper studies);
  * ``offload``  — surrogate-offload routing: offload decisions traced,
    queue wait collapses for trusted tasks.

Hard checks (non-zero exit on failure):
  * additivity: every per-task breakdown sums EXACTLY (1e-6) to the
    `TaskRecord.overhead` scalar it decomposes;
  * the exported Chrome trace passes `validate_chrome_trace` (B/E/X/i
    well-formed, per-track monotone timestamps);
  * the registry sampled a non-trivial timeseries aligned to the
    stepper ticks.

Writes ``BENCH_overhead_attribution.json`` plus a Perfetto-loadable
``TRACE_overhead_attribution.json`` for the elastic scenario (CI
uploads it as an artifact).

    PYTHONPATH=src python benchmarks/overhead_attribution.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List

from repro.chaos import FaultEvent, FaultPlan
from repro.cluster import (AutoAllocConfig, TraceTask, bursty_trace,
                           simulate_cluster)
from repro.core import backends
from repro.core.task import RetryPolicy
from repro.obs import (MetricsRegistry, Tracer, format_breakdown,
                       validate_chrome_trace)


def _elastic_cfg(walltime_s: float) -> AutoAllocConfig:
    return AutoAllocConfig(workers_per_alloc=2, walltime_s=walltime_s,
                           backlog_high_s=30.0, backlog_low_s=5.0,
                           max_pending=2, max_allocations=4,
                           min_allocations=0, idle_drain_s=20.0,
                           hysteresis_s=5.0)


class _TrustAll:
    """Deterministic offload engine for the routing scenario: trusts
    every task over the runtime budget (no GP, so the scenario is
    seed-stable)."""

    latency_s = 0.05
    n_virtual_workers = 2
    tracer = None

    def __init__(self, runtime_budget_s: float = 10.0):
        self.runtime_budget_s = runtime_budget_s
        self.n_considered = 0
        self.n_offloaded = 0

    def decide(self, req, cost=None):
        self.n_considered += 1
        offload = bool(cost and cost >= self.runtime_budget_s
                       and not req.config.get("_no_surrogate"))
        if offload:
            req.config["_surrogate"] = True
            self.n_offloaded += 1
        if self.tracer is not None:
            self.tracer.instant("offload.decide",
                                args={"task": req.task_id,
                                      "offload": offload})
        return offload

    def note_served(self):
        pass

    def observe(self, *a, **kw):
        pass


def run_scenario(name: str, spec, trace, **sim_kw) -> Dict[str, Any]:
    tracer = Tracer(capacity=262_144)
    registry = MetricsRegistry(max_samples=65_536)
    t0 = time.perf_counter()
    res = simulate_cluster(spec, trace, tracer=tracer, registry=registry,
                           **sim_kw)
    wall = time.perf_counter() - t0

    att = res.overhead_attribution
    problems: List[str] = []

    # additivity: the decomposition must reproduce §IV-A exactly
    rec_by = {r.task_id: r for r in res.records}
    worst = 0.0
    for tid, bd in att["per_task"].items():
        err = abs(bd.overhead_s - rec_by[tid].overhead)
        worst = max(worst, err)
        if err > 1e-6:
            problems.append(f"{name}: task {tid} decomposes to "
                            f"{bd.overhead_s:.6f}s but record overhead "
                            f"is {rec_by[tid].overhead:.6f}s")
    if att["n_tasks"] != len(res.records):
        problems.append(f"{name}: attribution covers {att['n_tasks']} "
                        f"tasks, records have {len(res.records)}")

    chrome = tracer.to_chrome()
    problems += [f"{name}: {p}" for p in validate_chrome_trace(chrome)]

    ts = registry.timeseries()
    if len(ts["t"]) < 2:
        problems.append(f"{name}: registry sampled {len(ts['t'])} ticks")
    if "queue_depth" not in ts or "busy_workers" not in ts:
        problems.append(f"{name}: registry missing cluster gauges "
                        f"({sorted(ts)})")

    print(f"\n[{name}] {len(res.records)} tasks, "
          f"{len(tracer.events())} events "
          f"({tracer.n_dropped} dropped), {len(ts['t'])} registry "
          f"samples, {wall*1e3:.0f} ms wall")
    print(format_breakdown(att))
    if worst > 0:
        print(f"  additivity worst |error|: {worst:.2e}s")

    return {
        "scenario": name,
        "n_tasks": len(res.records),
        "n_events": len(tracer.events()),
        "n_dropped": tracer.n_dropped,
        "n_registry_samples": len(ts["t"]),
        "wall_s": wall,
        "totals": att["totals"],
        "additivity_worst_err_s": worst,
        "problems": problems,
        "_tracer": tracer,
        "_timeseries": ts,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller traces")
    ap.add_argument("--json", default="BENCH_overhead_attribution.json")
    ap.add_argument("--trace-out",
                    default="TRACE_overhead_attribution.json")
    args = ap.parse_args(argv)

    spec = backends.get("hq")
    bursts, size = (2, 10) if args.quick else (4, 24)

    scenarios = []
    scenarios.append(run_scenario(
        "static", spec,
        bursty_trace(n_bursts=bursts, burst_size=size, seed=1),
        n_workers=3, seed=1))
    scenarios.append(run_scenario(
        "elastic", spec,
        bursty_trace(n_bursts=bursts, burst_size=size, seed=3),
        autoalloc=_elastic_cfg(walltime_s=60.0), max_attempts=6, seed=3))
    from repro.cluster import Broker
    offload_broker = Broker()
    offload_broker.attach_surrogate(_TrustAll(runtime_budget_s=10.0))
    scenarios.append(run_scenario(
        "offload", spec,
        bursty_trace(n_bursts=bursts, burst_size=size, runtime_s=30.0,
                     hints=True, seed=5),
        broker=offload_broker,
        autoalloc=_elastic_cfg(walltime_s=300.0), seed=5))

    # hedged: a p95 straggler pinned to a chaos-degraded node (4x
    # compute) triggers predictor-gated speculative re-execution; the
    # healthy hedge copy WINS, so the task's record carries the
    # straggler-detection lag as a speculation_s component the
    # balancing must absorb exactly (at seed 7 the 90 s straggler
    # dispatches on worker 0 at t = 37.38; the slow fault lands before,
    # the healthy copy wins at t = 239.42 vs the loser's 398.4)
    hedge_trace = [TraceTask(t=i * 0.5, runtime=2.0) for i in range(14)]
    hedge_trace += [TraceTask(t=7.0, runtime=120.0),
                    TraceTask(t=7.5, runtime=90.0)]
    scenarios.append(run_scenario(
        "hedged", spec, hedge_trace,
        autoalloc=AutoAllocConfig(workers_per_alloc=2, walltime_s=300.0,
                                  backlog_high_s=10.0, backlog_low_s=2.0,
                                  max_pending=3, max_allocations=6,
                                  min_allocations=1, idle_drain_s=30.0,
                                  hysteresis_s=5.0),
        max_workers=12, max_attempts=6, seed=7,
        fault_plan=FaultPlan(events=(
            FaultEvent(t=20.0, kind="slow_node", target=0,
                       factor=4.0, duration_s=150.0),)),
        straggler_factor=4.0, straggler_min_completed=5))

    # chaos: a poison task crash-kills its worker until quarantined —
    # retry_s covers the backoff-extended burned attempts, quarantine_s
    # the final one, speculation_s stays exactly zero (nothing hedged).
    # Crash times sit inside the task's run window (at seed 9 the
    # single static allocation's modelled SLURM queue wait puts the
    # first dispatch at t = 665.33).
    scenarios.append(run_scenario(
        "chaos", spec, [TraceTask(t=0.0, runtime=500.0)],
        n_workers=1, max_attempts=10, seed=9,
        fault_plan=FaultPlan(events=tuple(
            FaultEvent(t=700.0 + 40.0 * i, kind="worker_crash")
            for i in range(4))),
        retry_policy=RetryPolicy(base_s=1.0, factor=2.0, jitter=0.2,
                                 quarantine_after=3)))

    # the elastic scenario has the richest lifecycle: export its trace
    elastic = next(s for s in scenarios if s["scenario"] == "elastic")
    elastic["_tracer"].write_chrome(args.trace_out)
    print(f"\nwrote {args.trace_out} "
          f"({len(elastic['_tracer'].events())} events, Perfetto-loadable)")

    problems = [p for s in scenarios for p in s["problems"]]
    # cross-scenario expectations: the components the scenarios exist
    # to surface actually showed up
    if scenarios[1]["totals"]["retry_s"] <= 0:
        problems.append("elastic: walltime kills produced no retry_s")
    if scenarios[1]["totals"]["alloc_wait_s"] <= 0:
        problems.append("elastic: autoalloc bootstrap produced no "
                        "alloc_wait_s")
    if scenarios[0]["totals"]["queue_wait_s"] <= 0:
        problems.append("static: bursty arrivals produced no queue_wait_s")
    hedged = next(s for s in scenarios if s["scenario"] == "hedged")
    chaos = next(s for s in scenarios if s["scenario"] == "chaos")
    if hedged["totals"]["speculation_s"] <= 0:
        problems.append("hedged: speculative re-execution produced no "
                        "speculation_s")
    if chaos["totals"]["quarantine_s"] <= 0:
        problems.append("chaos: poison task produced no quarantine_s")
    # speculation is a hedging-only component: any non-zero value in a
    # scenario without stragglers means the balancing leaked
    for s in scenarios:
        if s["scenario"] != "hedged" and s["totals"]["speculation_s"] != 0:
            problems.append(f"{s['scenario']}: speculation_s = "
                            f"{s['totals']['speculation_s']} without "
                            f"hedging")

    out = {
        "bench": "overhead_attribution",
        "quick": bool(args.quick),
        "scenarios": [{k: v for k, v in s.items()
                       if not k.startswith("_")} for s in scenarios],
        "timeseries": {s["scenario"]: s["_timeseries"]
                       for s in scenarios},
        "problems": problems,
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.json}")

    if problems:
        print("\nFAIL:")
        for p in problems:
            print(f"  {p}")
        return 1
    print("\nall attribution checks PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
