"""Benchmark: UM-Bridge SLURM backend vs naive SLURM (paper Appendix A,
Figs. 5-6).  GS2 only, as in the paper: the UM-Bridge SLURM backend
submits per-server sbatch jobs and therefore shows NO gain over naive
SLURM (it adds the ~1 s server init)."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs import workloads
from repro.core import backends, eval_records, metrics, simulate

SEEDS = (3, 7, 13, 29, 41)


def run(n_evals: int = workloads.N_EVALS) -> List[Dict]:
    rows = []
    w = workloads.make_workload("gs2", n_evals=n_evals)
    for q in workloads.QUEUE_DEPTHS:
        for backend in ("slurm", "umb-slurm"):
            mk, cpu, ovh = [], [], []
            for seed in SEEDS:
                recs = eval_records(
                    simulate(backends.get(backend), w, q, seed=seed))
                s = metrics.summarize("gs2", backend, recs)
                mk.append(s.makespan)
                cpu.append(s.total_cpu_time)
                ovh.append(s.overhead_stats["median"])
            rows.append({"bench": "gs2", "scheduler": backend, "queue": q,
                         "makespan_mean": float(np.mean(mk)),
                         "cpu_time_mean": float(np.mean(cpu)),
                         "overhead_median": float(np.mean(ovh))})
    return rows
