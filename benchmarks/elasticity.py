"""Benchmark: static worker pools vs autoalloc on bursty arrival traces.

The elasticity claim behind HQ's autoalloc: on campaign-style UQ usage
(bursts of evaluations separated by think-time gaps), a fixed pool either
idles nodes through every gap (big pool) or drags the makespan out (small
pool); an autoallocator that submits bulk allocations when backlog *cost*
rises and drains them when they idle should spend fewer node-seconds than
the best fixed pool at a bounded makespan penalty.

Each row is one pool configuration on the same seeded bursty trace,
averaged over several seeds (everything deterministic per seed):

  * ``static-N`` — one allocation of N workers held for the whole
    campaign (what `Executor(n_workers=N)` without autoalloc does);
  * ``autoalloc`` — zero standing capacity; `AutoAllocator` submits
    4-worker/600 s allocations from backlog cost and drains idle ones.

Headline: autoalloc node-seconds vs the best-makespan static row, and
the makespan penalty paid for the saving (acceptance: saving > 0 at
penalty <= 10 %).

CI-feasible: pure-python discrete-event simulation.

    PYTHONPATH=src python benchmarks/elasticity.py [--quick]
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Tuple

import numpy as np

from repro.cluster import AutoAllocConfig, bursty_trace, simulate_cluster
from repro.core import backends

SEEDS = (3, 7, 13)
STATIC_COUNTS = (2, 4, 8)


def make_trace(seed: int, quick: bool = False):
    if quick:
        return bursty_trace(n_bursts=2, burst_size=10, gap_s=300.0,
                            runtime_s=10.0, seed=seed)
    return bursty_trace(n_bursts=4, burst_size=24, gap_s=600.0,
                        runtime_s=20.0, seed=seed)


def autoalloc_config(quick: bool = False) -> AutoAllocConfig:
    return AutoAllocConfig(
        workers_per_alloc=4, walltime_s=300.0 if quick else 600.0,
        backlog_high_s=40.0, backlog_low_s=10.0,
        max_pending=2, max_allocations=6, min_allocations=0,
        idle_drain_s=30.0, hysteresis_s=5.0)


def run(seeds: Tuple[int, ...] = SEEDS, quick: bool = False) -> List[Dict]:
    spec = backends.get("hq")
    rows: List[Dict] = []
    configs = [(f"static-{n}", {"n_workers": n}) for n in STATIC_COUNTS]
    configs.append(("autoalloc", {"autoalloc": autoalloc_config(quick)}))
    for label, kw in configs:
        mk, ns, util, nalloc = [], [], [], []
        for seed in seeds:
            trace = make_trace(seed, quick)
            # a static pool must request walltime covering the campaign
            if "n_workers" in kw:
                span = max(tt.t for tt in trace)
                kw = dict(kw, walltime_s=span + 1200.0)
            res = simulate_cluster(spec, trace, seed=seed, **kw)
            s = res.summary()
            assert s["n_ok"] == s["n_tasks"], (label, seed, s)
            mk.append(s["makespan"])
            ns.append(s["node_seconds"])
            util.append(s["utilization"])
            nalloc.append(s["n_allocations"])
        rows.append({
            "pool": label,
            "makespan_mean": float(np.mean(mk)),
            "node_seconds_mean": float(np.mean(ns)),
            "utilization_mean": float(np.mean(util)),
            "allocations_mean": float(np.mean(nalloc)),
        })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    """Headline: autoalloc vs the best-makespan static pool."""
    static = [r for r in rows if r["pool"].startswith("static")]
    auto = next(r for r in rows if r["pool"] == "autoalloc")
    best = min(static, key=lambda r: r["makespan_mean"])
    return {
        "best_static": best["pool"],
        "node_seconds_saving":
            1.0 - auto["node_seconds_mean"] / best["node_seconds_mean"],
        "makespan_penalty":
            auto["makespan_mean"] / best["makespan_mean"] - 1.0,
        "utilization_gain":
            auto["utilization_mean"] - best["utilization_mean"],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny trace + one seed (CI smoke)")
    args = ap.parse_args()
    seeds = SEEDS[:1] if args.quick else SEEDS
    rows = run(seeds=seeds, quick=args.quick)
    cols = ("pool", "makespan_mean", "node_seconds_mean",
            "utilization_mean", "allocations_mean")
    print("| " + " | ".join(cols) + " |")
    print("|" + "|".join("---" for _ in cols) + "|")
    for r in rows:
        print("| " + " | ".join(
            f"{r[c]:.2f}" if isinstance(r[c], float) else str(r[c])
            for c in cols) + " |")
    print()
    d = derived(rows)
    print(f"best static pool     : {d['best_static']}")
    print(f"node-seconds saving  : {d['node_seconds_saving']:+.1%}")
    print(f"makespan penalty     : {d['makespan_penalty']:+.1%}")
    print(f"utilization gain     : {d['utilization_gain']:+.2f}")
    ok = d["node_seconds_saving"] > 0.0 and d["makespan_penalty"] <= 0.10
    print(f"elasticity claim (saving>0 at <=10% penalty): "
          f"{'PASS' if ok else 'FAIL'}")


if __name__ == "__main__":
    main()
