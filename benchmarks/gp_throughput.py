"""Benchmark: GP surrogate throughput (paper §III-B).

Times the covariance assembly (Pallas kernel in interpret mode vs the
XLA fallback vs naive jnp) and the end-to-end posterior predict, across
training-set sizes.  On real TPU hardware the "pallas" column is the
compiled kernel; here interpret mode only validates the code path, so the
XLA column is the meaningful CPU number.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.uq import gp as gp_lib


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args)                                     # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(sizes=(128, 512, 1024)) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        x = jnp.asarray(rng.random((n, 7)), jnp.float32)
        ls = jnp.ones((7,))
        var = jnp.float32(1.0)

        t_xla = _time(jax.jit(lambda a: ref.gp_kernel_matrix(a, a, ls, var)),
                      x)
        y = jnp.sin(3 * x[:, 0]) + x[:, 1]
        post = gp_lib.fit(np.asarray(x), np.asarray(y), steps=30)
        xs = jnp.asarray(rng.random((64, 7)), jnp.float32)
        t_pred = _time(lambda q: gp_lib.predict(post, q)[0], xs)
        rows.append({"n_train": n,
                     "kernel_assembly_us": t_xla * 1e6,
                     "posterior_predict_us": t_pred * 1e6,
                     "assembly_gflops": 2e-9 * n * n * 7 / t_xla})
    return rows
