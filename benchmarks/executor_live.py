"""Benchmark: live-executor scheduling of real JAX tasks.

The paper's experiment transplanted onto real computation: N GS2-proxy
solves (genuinely variable runtime) + N GP-surrogate predictions through
the persistent-worker executor (HQ semantics) vs fresh-server-per-task
(naive SLURM semantics).  Reports wall time, total CPU, init share and
SLR from real clocks.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import EvalRequest, Executor, LambdaModel
from repro.core.metrics import summarize
from repro.uq import gp as gp_lib
from repro.uq import gs2_proxy, sampling


def _gs2_factory():
    solver = gs2_proxy.make_solver(m=48)          # per-server jit cache

    def fn(parameters, config):
        g, f = solver(np.asarray(parameters[0], np.float32))
        return [[g, f]]

    def warm():
        solver(np.full(7, 0.5, np.float32))

    return LambdaModel("gs2", fn, 7, 2, warmup_fn=warm)


def _gp_factory():
    thetas = sampling.latin_hypercube(48, seed=0)
    ys = np.stack([[0.1 * t[3] * t[6], 0.05 * t[1]] for t in thetas])
    post = gp_lib.fit(thetas, ys, steps=40)

    def fn(parameters, config):
        mean, _ = gp_lib.predict(post, np.asarray(parameters, np.float32))
        return np.asarray(mean).tolist()

    return LambdaModel("gp", fn, 7, 2,
                       warmup_fn=lambda: fn([thetas[0].tolist()], None))


def run(n_tasks: int = 24, n_workers: int = 4) -> List[Dict]:
    thetas = sampling.latin_hypercube(n_tasks, seed=5)
    rows = []
    for persistent in (True, False):
        factories = {"gs2": _gs2_factory, "gp": _gp_factory}
        t0 = time.monotonic()
        with Executor(factories, n_workers=n_workers,
                      persistent_servers=persistent) as ex:
            reqs = []
            for i, th in enumerate(thetas):
                name = "gs2" if i % 2 == 0 else "gp"
                reqs.append(EvalRequest(name, [th.tolist()]))
            results = ex.run_all(reqs, timeout=600.0)
            recs = ex.records()
        wall = time.monotonic() - t0
        ok = sum(r.status == "ok" for r in results)
        s = summarize("live", "hq" if persistent else "slurm", recs)
        rows.append({
            "mode": "persistent(HQ)" if persistent else "fresh(SLURM)",
            "n_tasks": n_tasks, "ok": ok, "wall_s": wall,
            "total_cpu_s": s.total_cpu_time,
            "init_share": 1.0 - s.total_compute / max(s.total_cpu_time,
                                                      1e-9),
            "slr": s.slr,
        })
    return rows
