"""Benchmark: live-executor scheduling of real JAX tasks.

The paper's experiment transplanted onto real computation: N GS2-proxy
solves (genuinely variable runtime) + N GP-surrogate predictions through
the persistent-worker executor (HQ semantics) vs fresh-server-per-task
(naive SLURM semantics).  Reports wall time, total CPU, init share and
SLR from real clocks.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import EvalRequest, Executor, LambdaModel
from repro.core.metrics import summarize
from repro.obs import Tracer
from repro.uq import gp as gp_lib
from repro.uq import gs2_proxy, sampling


def _gs2_factory():
    solver = gs2_proxy.make_solver(m=48)          # per-server jit cache

    def fn(parameters, config):
        g, f = solver(np.asarray(parameters[0], np.float32))
        return [[g, f]]

    def warm():
        solver(np.full(7, 0.5, np.float32))

    return LambdaModel("gs2", fn, 7, 2, warmup_fn=warm)


def _gp_factory():
    thetas = sampling.latin_hypercube(48, seed=0)
    ys = np.stack([[0.1 * t[3] * t[6], 0.05 * t[1]] for t in thetas])
    post = gp_lib.fit(thetas, ys, steps=40)

    def fn(parameters, config):
        mean, _ = gp_lib.predict(post, np.asarray(parameters, np.float32))
        return np.asarray(mean).tolist()

    return LambdaModel("gp", fn, 7, 2,
                       warmup_fn=lambda: fn([thetas[0].tolist()], None))


def run(n_tasks: int = 24, n_workers: int = 4,
        trace_out: str = None) -> List[Dict]:
    """Both modes, persistent(HQ) first.  ``trace_out`` streams the
    persistent-mode run's span trace to a JSONL file while it executes
    (`Tracer.stream_to`) — the recording `repro.obs.calib` calibrates
    the simulator's overhead model from and `repro.obs.replay` replays
    (see `benchmarks/calibration.py`)."""
    thetas = sampling.latin_hypercube(n_tasks, seed=5)
    rows = []
    for persistent in (True, False):
        factories = {"gs2": _gs2_factory, "gp": _gp_factory}
        t0 = time.monotonic()
        tracer = None
        kw = {}
        if trace_out and persistent:
            # zero-based clock so the trace's virtual timeline starts at
            # ~0 like a sim trace (monotonic() origin is arbitrary)
            tracer = Tracer().stream_to(trace_out)
            kw = {"tracer": tracer,
                  "clock": lambda: time.monotonic() - t0}
        with Executor(factories, n_workers=n_workers,
                      persistent_servers=persistent, **kw) as ex:
            reqs = []
            for i, th in enumerate(thetas):
                name = "gs2" if i % 2 == 0 else "gp"
                reqs.append(EvalRequest(name, [th.tolist()]))
            results = ex.run_all(reqs, timeout=600.0)
            recs = ex.records()
        if tracer is not None:
            tracer.close_stream()
        wall = time.monotonic() - t0
        ok = sum(r.status == "ok" for r in results)
        s = summarize("live", "hq" if persistent else "slurm", recs)
        rows.append({
            "mode": "persistent(HQ)" if persistent else "fresh(SLURM)",
            "n_tasks": n_tasks, "ok": ok, "wall_s": wall,
            "total_cpu_s": s.total_cpu_time,
            "init_share": 1.0 - s.total_compute / max(s.total_cpu_time,
                                                      1e-9),
            "slr": s.slr,
        })
    return rows


def main() -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-tasks", type=int, default=24)
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--trace-out", default=None,
                    help="stream the persistent(HQ) run's span trace to "
                         "this JSONL path (calibration/replay input)")
    args = ap.parse_args()
    rows = run(args.n_tasks, args.n_workers, trace_out=args.trace_out)
    print(json.dumps(rows, indent=2))
    if args.trace_out:
        print(f"trace written to {args.trace_out}")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
