"""Benchmark: surrogate conditioning + re-costing cost across engines.

The paper's UQ workloads stream completions into an online surrogate;
PR 5 made the queues O(log n), which left the surrogate itself as the
scaling wall: the exact engine pays one O(n³) Cholesky refactorisation
per conditioning batch.  This benchmark is the perf anchor for the
pluggable `repro.uq.engine` backends — it measures, at each training-set
size n:

  * conditioning latency for one k-point batch on every backend:
    ``exact`` (full refactor, O(n³)), ``incremental`` (rank-k block
    Cholesky update, O(n²k)), ``partitioned`` (cap-bounded expert
    refactor, O(cap³) — flat in n);
  * re-cost latency: one warm bucket-padded `predict_batch` pass over a
    1024-query batch per backend (the queue re-scoring hot path).

Pass criteria (printed, and non-zero exit on failure):
  * with ``--quick`` (the CI gate): incremental conditioning at the
    gate size (default n=5000) is >= ``--min-speedup`` (default 10x)
    faster than exact — the ISSUE's acceptance bar;
  * partitioned conditioning latency does not grow with n (the largest
    size costs <= 5x the smallest — "flat" with generous CI noise).

Writes every number to ``BENCH_gp_scale.json`` (``--json`` to move it)
so future PRs can diff the trajectory.

    PYTHONPATH=src python benchmarks/gp_scale.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.uq import engine as engine_lib
from repro.uq import gp as gp_lib

SIZES = (512, 1_024, 2_048, 5_000, 8_000)
QUICK_SIZES = (1_024, 5_000)
COND_K = 8                     # points per conditioning batch
RECOST_Q = 1_024               # queries per re-cost pass
EXPERT_CAP = 256


def _dataset(n: int, d: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-2, 2, (n, d)).astype(np.float32)
    y = (np.sin(x[:, 0]) + 0.3 * x[:, 1] - 0.1 * x[:, 2] * x[:, 3]
         + 0.05 * rng.standard_normal(n)).astype(np.float32)[:, None]
    return x, y


def _base_posterior(x, y) -> gp_lib.GPPosterior:
    """One exact factorisation at size n under fixed hyperparameters —
    type-II MLE at every n would swamp the numbers being measured."""
    params = gp_lib.GPParams.init(x.shape[1])
    x = jnp.asarray(x, jnp.float32)
    y2 = jnp.asarray(y, jnp.float32)
    mean = jnp.mean(y2, axis=0)
    std = jnp.maximum(jnp.std(y2, axis=0), 1e-8)
    chol = gp_lib.chol_factor(params, x, "rbf")
    alpha = jax.scipy.linalg.cho_solve((chol, True), (y2 - mean) / std)
    return gp_lib.GPPosterior(params=params, x=x, y=y2, y_mean=mean,
                              y_std=std, chol=chol, alpha=alpha,
                              kind="rbf")


def _block(engine) -> None:
    """Force pending device work so wall timings are honest."""
    if engine.backend == "incremental":
        return              # numpy factor lineage: already synchronous
    if engine.backend == "partitioned":
        for e in engine.experts:
            jax.block_until_ready(e.chol)
        return
    jax.block_until_ready(engine.post.chol)
    jax.block_until_ready(engine.post.alpha)


def _time_condition(engine, xk, yk, repeats: int) -> float:
    """Median seconds for one k-point conditioning batch, streaming:
    each repeat conditions the PREVIOUS repeat's engine — the successor
    chain a real completion stream walks.  (Re-conditioning a stale
    generation instead would fork the incremental factor lineage and
    bill an O(n²) defensive copy the hot path never pays.)  Size creep
    is repeats*k points on n — noise next to the backend gaps.

    The jax backends get a throwaway warm chain through the SAME size
    sequence first, so the timings measure factorisation math, not XLA
    retracing of each new shape (the incremental backend's conditioning
    path is numpy/LAPACK — nothing to warm, and a warm chain would
    advance the shared factor lineage and force forks)."""
    if engine.backend != "incremental":
        warm = engine
        for r in range(repeats):
            warm = warm.condition(xk + 1e-3 * (r + repeats), yk)
        _block(warm)
    ts = []
    for r in range(repeats):
        t0 = time.perf_counter()
        engine = engine.condition(xk + 1e-3 * r, yk)
        _block(engine)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _time_recost(engine, xq, repeats: int) -> float:
    engine.predict_batch(xq)                   # warm the bucket shapes
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        mean, _ = engine.predict_batch(xq)
        jax.block_until_ready(mean)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_size(n: int, repeats: int = 3, seed: int = 0) -> Dict:
    x, y = _dataset(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    xk = rng.uniform(-2, 2, (COND_K, x.shape[1])).astype(np.float32)
    yk = rng.standard_normal((COND_K, 1)).astype(np.float32)
    xq = rng.uniform(-2, 2, (RECOST_Q, x.shape[1])).astype(np.float32)

    post = _base_posterior(x, y)
    jax.block_until_ready(post.chol)
    row: Dict = {"n": n, "k": COND_K, "recost_q": RECOST_Q,
                 "condition_s": {}, "recost_s": {}}

    for backend in engine_lib.BACKENDS:
        kw = {"expert_cap": EXPERT_CAP} if backend == "partitioned" else {}
        eng = engine_lib.wrap_posterior(post, backend, **kw)
        if backend == "incremental":
            # amortised steady state: the periodic refactor is the
            # hygiene tail, the block update is the per-batch price
            eng = eng.condition(xk - 1e-3, yk)   # leave the "fresh" state
            _block(eng)
        row["condition_s"][backend] = _time_condition(eng, xk, yk, repeats)
        row["recost_s"][backend] = _time_recost(eng, xq, repeats)
    row["speedup_incremental"] = (row["condition_s"]["exact"]
                                  / row["condition_s"]["incremental"])
    row["speedup_partitioned"] = (row["condition_s"]["exact"]
                                  / row["condition_s"]["partitioned"])
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI gate: two sizes + hard speedup criterion")
    ap.add_argument("--json", default="BENCH_gp_scale.json")
    ap.add_argument("--gate-n", type=int, default=5_000,
                    help="training-set size the speedup gate measures at")
    ap.add_argument("--min-speedup", type=float, default=10.0,
                    help="--quick fails if incremental conditioning is "
                         "not this many times faster than exact at gate-n")
    args = ap.parse_args(argv)

    sizes = QUICK_SIZES if args.quick else SIZES
    if args.gate_n not in sizes:
        sizes = tuple(sorted(set(sizes) | {args.gate_n}))
    rows: List[Dict] = []
    print(f"gp-scale: sizes={list(sizes)} backends={list(engine_lib.BACKENDS)}"
          f" (k={COND_K} per batch, cap={EXPERT_CAP})")
    for n in sizes:
        row = bench_size(n, repeats=2 if args.quick else 3)
        rows.append(row)
        c, r = row["condition_s"], row["recost_s"]
        print(f"  n={n:>6,}  condition: "
              f"exact {c['exact']*1e3:>9.1f} ms | "
              f"incr {c['incremental']*1e3:>7.1f} ms "
              f"({row['speedup_incremental']:>6.1f}x) | "
              f"part {c['partitioned']*1e3:>7.1f} ms "
              f"({row['speedup_partitioned']:>6.1f}x)")
        print(f"          recost({RECOST_Q}): "
              f"exact {r['exact']*1e3:>9.1f} ms | "
              f"incr {r['incremental']*1e3:>7.1f} ms | "
              f"part {r['partitioned']*1e3:>7.1f} ms")

    gate_row = next(r for r in rows if r["n"] == args.gate_n)
    part_first = rows[0]["condition_s"]["partitioned"]
    part_last = rows[-1]["condition_s"]["partitioned"]
    criteria = {
        "gate_n": args.gate_n,
        "min_speedup": args.min_speedup,
        "speedup_incremental_at_gate": gate_row["speedup_incremental"],
        "incremental_gate_ok":
            gate_row["speedup_incremental"] >= args.min_speedup,
        "partitioned_flat_ratio": part_last / max(part_first, 1e-12),
        "partitioned_flat_ok": part_last <= 5.0 * part_first,
    }
    payload = {"bench": "gp_scale", "quick": args.quick, "rows": rows,
               "criteria": criteria}
    with open(args.json, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"  wrote {args.json}")

    ok = True
    msg = (f"incremental speedup at n={args.gate_n}: "
           f"{criteria['speedup_incremental_at_gate']:.1f}x "
           f"(need >= {args.min_speedup:.0f}x)")
    if args.quick and not criteria["incremental_gate_ok"]:
        print(f"  FAIL {msg}")
        ok = False
    else:
        print(f"  PASS {msg}")
    msg = (f"partitioned conditioning flat in n: "
           f"{part_first*1e3:.1f} ms -> {part_last*1e3:.1f} ms "
           f"({criteria['partitioned_flat_ratio']:.2f}x, need <= 5x)")
    if not criteria["partitioned_flat_ok"]:
        print(f"  FAIL {msg}")
        ok = False
    else:
        print(f"  PASS {msg}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
